"""Benchmark harness: one entry per paper table/figure + kernel micros
+ the roofline table.  Prints ``name,value,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--scale ci|mid|paper] [--only X]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time


def _csv(name, *fields):
    print(",".join([name] + [str(f) for f in fields]), flush=True)


def bench_paper(scale: str, only=None) -> None:
    from benchmarks import paper_experiments as pe

    if only in (None, "increments"):
        for sampling in ("edge", "snowball"):
            rows, wall = pe.bench_cycles_per_increment(scale, sampling)
            for r in rows:
                _csv(f"fig8_9/{sampling}", f'inc{r["increment"]}',
                     f'edges={r["edges"]}',
                     f'ingest_cycles={r["ingest_cycles"]}',
                     f'ingest_bfs_cycles={r["ingest_bfs_cycles"]}')
    if only in (None, "energy"):
        for r in pe.bench_energy(scale):
            _csv("table2", r["sampling"], r["mode"],
                 f'energy_uj={r["energy_uj"]}', f'time_us={r["time_us"]}')
    if only in (None, "allocator"):
        for r in pe.bench_allocator(scale):
            _csv("fig5_allocator", r["allocator"],
                 f'cycles={r["cycles"]}', f'hops={r["hops"]}',
                 f'ghosts={r["ghosts"]}',
                 f'mean_ghost_hops={r["mean_ghost_hops"]}',
                 f'max_ghost_hops={r["max_ghost_hops"]}')
    if only in (None, "activation"):
        act = pe.bench_activation(scale, "edge",
                                  out_npz="results/activation_edge.npz")
        for mode, s in act.items():
            _csv("fig6_7_activation", mode, f'cycles={s["cycles"]}',
                 f'mean_active={s["mean_active"]}',
                 f'peak={s["peak_active"]}',
                 f'util_pct={s["mean_util_pct"]}')
    if only in (None, "skew"):
        for r in pe.bench_skew(scale):
            _csv("skew_rhizome", f'rhizome_cap={r["rhizome_cap"]}',
                 f'cycles={r["cycles"]}', f'hops={r["hops"]}',
                 f'stalls={r["stalls"]}',
                 f'max_degree={r["max_degree"]}',
                 f'deg_over_edge_cap={r["degree_over_edge_cap"]}',
                 f'rhizomes={r["rhizomes"]}',
                 f'multi_root={r["multi_root_vertices"]}',
                 f'max_fanout={r["max_fanout"]}',
                 f'ghosts={r["ghosts"]}')
    if only in (None, "skew", "lanes"):
        # virtual lanes on the same R-MAT stream at the PRE-oversize
        # queue_cap (results/bench_lanes.json; the CI lanes-smoke gate:
        # lanes>=2 must complete where lanes=1 livelocks, DESIGN §7)
        rows, base = pe.bench_lanes(scale)
        for r in rows:
            _csv("lanes_hub", f'lanes={r["lanes"]}',
                 f'queue_cap={r["queue_cap"]}', r["status"],
                 f'cycles={r["cycles"]}', f'stalls={r["stalls"]}')
        _csv("lanes_hub", "lanes=1", f'queue_cap={base["queue_cap"]}',
             f'{base["status"]} (oversize baseline)',
             f'cycles={base["cycles"]}', f'stalls={base["stalls"]}')
    if only in (None, "throughput"):
        t = pe.bench_engine_throughput(scale)
        _csv("engine_throughput", f'cycles={t["cycles"]}',
             f'wall_s={t["wall_s"]}',
             f'cell_cycles_per_s={t["cell_cycles_per_s"]}')


def bench_engine_backends(scale: str, profile: bool = False) -> None:
    """jnp vs pallas cycle-megakernel backends: throughput, bit-exact
    parity gate, livelock-detector smoke (results/bench_engine.json).
    ``--profile`` adds the telemetry-on runs: overhead, frame counts and
    the trace/heatmap dumps under ``results/profile/`` (DESIGN §8)."""
    from benchmarks.engine_throughput import bench_engine
    r = bench_engine(scale, profile=profile)
    for backend, b in r["backends"].items():
        _csv("engine_backend", backend, f'cycles={b["cycles"]}',
             f'wall_s={b["wall_s"]}',
             f'cell_cycles_per_s={b["cell_cycles_per_s"]}')
        if "profile" in b:
            pr = b["profile"]
            _csv("engine_profile", backend,
                 f'overhead_pct={pr["overhead_pct"]}',
                 f'frames={pr["frames"]}',
                 f'execs_per_cycle={pr["rates"]["execs_per_cycle"]}',
                 f'hops_per_cycle={pr["rates"]["hops_per_cycle"]}',
                 f'trace={pr["trace"]}', f'heatmap={pr["heatmap"]}')
    _csv("engine_backend", "parity", r["parity"])
    for backend, v in r["livelock_detector"].items():
        _csv("engine_backend", f"livelock_{backend}", v)
    if "resilience_profile" in r:
        pr = r["resilience_profile"]
        for k in ("ckpt_every_1", "ckpt_every_2", "faults_zero_rate",
                  "faults_live"):
            _csv("resilience_profile", k, f'wall_s={pr[k]["wall_s"]}',
                 f'overhead_pct={pr[k]["overhead_pct"]}')


def bench_faults(scale: str, profile: bool = False) -> None:
    """Resilience gates (DESIGN §9): seeded fault stream converging
    exact via repair, kill-and-resume bit-exactness, livelock recovery
    via escalation — both backends (results/bench_engine.json)."""
    from benchmarks.resilience_smoke import bench_resilience
    r = bench_resilience(scale, profile=profile)
    for backend, b in r["fault_smoke"].items():
        _csv("fault_smoke", backend, b["status"], f'cycles={b["cycles"]}',
             f'dropped={b["dropped"]}', f'duplicated={b["duplicated"]}',
             f'corrupted={b["corrupted"]}',
             f'blackout_hits={b["blackout_hits"]}')
    for backend, b in r["kill_resume"].items():
        _csv("kill_resume", backend, b["status"],
             f'resumed_at={b["resumed_at"]}')
    rc = r["recovery"]
    _csv("livelock_recovery", rc["status"],
         f'escalated_lanes={rc["escalated_lanes"]}',
         f'attempts={rc["attempts"]}', f'wedge_cycle={rc["wedge_cycle"]}')
    if profile:
        pr = r["profile"]
        for k in ("ckpt_every_1", "ckpt_every_2", "faults_zero_rate",
                  "faults_live"):
            _csv("resilience_profile", k, f'wall_s={pr[k]["wall_s"]}',
                 f'overhead_pct={pr[k]["overhead_pct"]}')


def bench_serve(scale: str) -> None:
    """Multi-tenant query serving (repro.mq, DESIGN §10): Q=8 mixed
    BFS/SSSP/CC/widest batch over a live R-MAT stream vs Q serial runs
    (results/bench_serve.json).  Fails loudly if any tenant's values
    diverge from its single-query run or the aggregate speedup falls
    under 2x — the CI serve-smoke gate."""
    from benchmarks.serve_bench import bench_serve as run_serve
    r = run_serve(scale)
    for qrec in r["queries"]:
        _csv("serve_query", f'slot={qrec["slot"]}', qrec["app"],
             f'source={qrec["source"]}',
             f'serial_cycles={qrec["serial_cycles"]}',
             "exact" if qrec["exact"] else "MISMATCH")
    _csv("serve_batch", f'qbatch={r["qbatch"]}',
         f'batch_cycles={r["batch_cycles"]}',
         f'serial_total={r["serial_cycles_total"]}',
         f'speedup={r["speedup"]}',
         f'p50={r["p50_cycles"]}', f'p99={r["p99_cycles"]}',
         f'deferrals={r["deferrals"]}')
    if not r["all_exact"]:
        raise SystemExit("bench_serve: per-query values diverged from "
                         "the single-query runs")
    if r["speedup"] < 2.0:
        raise SystemExit(f'bench_serve: aggregate speedup {r["speedup"]} '
                         "< 2x over serial runs")


def bench_dist(scale: str) -> None:
    """Sharded-CCA chunk throughput at 1/2/4/8 fake host devices."""
    from benchmarks.dist_scaling import run_scaling
    failed = []
    for r in run_scaling(scale):
        if "error" in r:
            failed.append(r["devices"])
            _csv("dist_scaling", f'devices={r["devices"]}', "FAILED",
                 r["error"][:120].replace("\n", " "))
            continue
        _csv("dist_scaling", f'devices={r["devices"]}', f'grid={r["grid"]}',
             f'cell_cycles_per_s={r["cell_cycles_per_s"]}',
             f'wall_s={r["wall_s"]}', f'compile_s={r["compile_s"]}')
    if failed:  # fail loudly so the CI dist-smoke job goes red
        raise SystemExit(f"bench_dist failed at device counts {failed}")


def bench_kernels() -> None:
    import jax
    import numpy as np
    from repro.kernels.embedding_bag.ops import embedding_bag
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.spmm.ops import spmm_sorted_coo

    def timeit(f, *a, n=3, **kw):
        f(*a, **kw)  # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(f(*a, **kw))
        return (time.time() - t0) / n * 1e6

    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 256, 4, 64))
    kk = jax.random.normal(k, (1, 256, 2, 64))
    us = timeit(flash_attention, q, kk, kk, interpret=True)
    _csv("kernel/flash_attention", f"{us:.0f}us",
         "interpret-mode (CPU); deploy target TPU")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((512, 64), dtype=np.float32)
    src = rng.integers(0, 512, 4096).astype(np.int32)
    dst = np.sort(rng.integers(0, 512, 4096).astype(np.int32))
    us = timeit(spmm_sorted_coo, x, src, dst, 512, interpret=True)
    _csv("kernel/spmm_onehot_mxu", f"{us:.0f}us", "interpret-mode")
    tbl = rng.standard_normal((4096, 64), dtype=np.float32)
    idx = rng.integers(0, 4096, (64, 4)).astype(np.int32)
    us = timeit(embedding_bag, tbl, idx, interpret=True)
    _csv("kernel/embedding_bag", f"{us:.0f}us", "interpret-mode")


def bench_roofline(path="results/dryrun.json") -> None:
    p = pathlib.Path(path)
    if not p.exists():
        _csv("roofline", "SKIPPED", f"{path} missing - run dryrun first")
        return
    data = json.loads(p.read_text())
    for key, r in sorted(data.items()):
        if not r.get("ok"):
            _csv("roofline", key, "FAILED", r.get("error", "")[:80])
            continue
        rf = r.get("roofline", {})
        _csv("roofline", key,
             f't_comp={rf.get("t_compute", 0):.4f}s',
             f't_mem={rf.get("t_memory", 0):.4f}s',
             f't_coll={rf.get("t_collective", 0):.4f}s',
             f'dominant={rf.get("dominant")}',
             f'frac={rf.get("roofline_fraction", 0):.3f}')


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="ci",
                    choices=["ci", "mid", "paper"])
    ap.add_argument("--only", default=None,
                    help="increments|energy|allocator|activation|skew|"
                         "lanes|throughput|engine|faults|dist|serve|"
                         "kernels|roofline")
    ap.add_argument("--profile", action="store_true",
                    help="telemetry-on engine runs (overhead + Chrome "
                         "trace + congestion heatmap under "
                         "results/profile/) and the resilience cost "
                         "profile (checkpoint cadence + fault deltas)")
    args = ap.parse_args()
    pathlib.Path("results").mkdir(exist_ok=True)
    print("benchmark,fields...", flush=True)
    try:
        if args.only in (None, "kernels"):
            bench_kernels()
        if args.only in (None, "roofline"):
            bench_roofline()
        if args.only in (None, "engine"):
            bench_engine_backends(args.scale, profile=args.profile)
        if args.only in (None, "faults"):
            bench_faults(args.scale, profile=args.profile)
        if args.only in (None, "dist"):
            bench_dist(args.scale)
        if args.only in (None, "serve"):
            bench_serve(args.scale)
        if args.only is None or args.only not in ("kernels", "roofline",
                                                  "engine", "faults",
                                                  "dist", "serve"):
            bench_paper(args.scale, args.only)
    except Exception as e:
        # a LivelockError message carries the flight-recorder wedge
        # report — print it whole so the CI log shows WHERE the machine
        # wedged, and exit nonzero so the job goes red (DESIGN §9)
        from repro.core.engine import LivelockError
        if isinstance(e, LivelockError):
            print(f"\nLIVELOCK (cycle {e.cycle}, chunk {e.chunk}):\n{e}",
                  file=sys.stderr, flush=True)
            raise SystemExit(3)
        raise


if __name__ == "__main__":
    main()
