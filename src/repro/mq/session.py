"""MQSession — serve Q concurrent queries over one evolving graph.

The session wraps a :class:`StreamingEngine` built from a qbatch=Q
composite app (``mq.app.batch_app``) and adds the tenant lifecycle
(DESIGN §10):

* **admit** a query mid-stream into a free slot: reset ONLY that slot's
  value plane to its app's neutral element (the live graph structure is
  shared and untouched) and inject a qsel-masked ``OP_APP`` seed at the
  source's canonical root — one message, relaxing exactly one tenant.
  Label-flood queries (CC) instead host-write every vertex's label and
  must be admitted before any edges stream in (existing edges never
  re-trigger; inserts do the propagation from then on).
* **track quiescence per query** from the ``qchg`` per-slot relax
  counters the execute stage accumulates: a slot whose counter stayed
  zero across an increment has settled, and ``qlast`` holds the exact
  cycle of its last relax (its time-to-quiescence end point).
* **retire / recycle** settled slots: readback with the slot app's own
  root combine, then the slot (with a bumped generation) is free for the
  next tenant — admitting a different app rebuilds the composite, which
  is just a jit recompile (the app is a static argument).

Admission happens only at increment boundaries, where the machine is
quiescent: no messages are in flight, so a recycled slot can never
observe a stale payload from its previous generation.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.alloc import rhizome_rcs
from repro.core.apps import APPS, DiffusionApp
from repro.core.config import EngineConfig
from repro.core.engine import StreamingEngine
from repro.core.msg import MSG_WORDS, OP_APP
from repro.core.state import root_addr
from repro.mq.app import batch_app

# default seed value per app family: the value a source vertex starts
# from (BFS/SSSP distance 0; widest bottleneck +INF; reliable prob 1)
DEFAULT_SEEDS = {"bfs": 0.0, "sssp": 0.0, "widest": 1e9, "reliable": 1.0}

# label-flood apps: admission = host label write at stream start, no
# seed message (every vertex is its own source)
LABEL_APPS = ("cc",)


@dataclasses.dataclass
class QuerySlot:
    """One tenant: app id + source + generation (ISSUE §10 slot tuple)."""
    app: DiffusionApp | None = None
    source: int = -1
    generation: int = 0
    state: str = "free"          # free | active | settled
    admit_cycle: int = 0
    settle_cycle: int | None = None   # qlast at first all-quiet boundary
    increments: int = 0

    @property
    def latency_cycles(self) -> int | None:
        if self.settle_cycle is None:
            return None
        return self.settle_cycle - self.admit_cycle


class MQSession:
    """Q-batched serving session over one StreamingEngine."""

    def __init__(self, cfg: EngineConfig, qbatch: int,
                 apps: "list[str] | None" = None):
        # slot apps are jit-static; start every slot on BFS (the cheapest
        # composite) — admit() rebuilds when a tenant needs another app
        names = list(apps) if apps else ["bfs"] * qbatch
        assert len(names) == qbatch
        self.composite = batch_app(names)
        self.eng = StreamingEngine(cfg, self.composite)
        self.slots = [QuerySlot() for _ in range(qbatch)]
        self.edges_seen = 0

    @property
    def qbatch(self) -> int:
        return self.eng.cfg.qbatch

    @property
    def slot_apps(self) -> tuple:
        return (self.composite.slot_apps if self.composite.qbatch > 1
                else (self.composite,))

    # ---------------- admission ----------------

    def free_slots(self) -> "list[int]":
        return [q for q, s in enumerate(self.slots) if s.state == "free"]

    def admit(self, app: str | DiffusionApp, source: int,
              slot: int | None = None, seed: float | None = None) -> int:
        """Admit a query into a free slot; returns the slot index.

        Single-source apps admit at any increment boundary.  Label-flood
        apps (CC) only before the first edge streams in.
        """
        a = APPS[app] if isinstance(app, str) else app
        if slot is None:
            free = self.free_slots()
            if not free:
                raise RuntimeError("no free query slot (retire one first)")
            slot = free[0]
        s = self.slots[slot]
        assert s.state == "free", f"slot {slot} is {s.state}"
        if a.name in LABEL_APPS and self.edges_seen:
            raise ValueError(
                f"label-flood app {a.name!r} must be admitted before the "
                "stream starts (existing edges never re-trigger)")
        if self.slot_apps[slot].name != a.name:
            self._rebuild(slot, a)
        self._reset_slot_plane(slot)
        cycle = int(self.eng.state.cycle)
        if a.name in LABEL_APPS:
            self._write_labels(slot)
        else:
            self._inject_seed(
                slot, source,
                DEFAULT_SEEDS[a.name] if seed is None else seed)
        self.slots[slot] = QuerySlot(app=a, source=source,
                                     generation=s.generation + 1,
                                     state="active", admit_cycle=cycle)
        return slot

    def _rebuild(self, slot: int, a: DiffusionApp):
        names = [sa.name for sa in self.slot_apps]
        names[slot] = a.name
        self.composite = batch_app(names)
        self.eng.app = self.composite
        # n_vals / qbatch are unchanged, so the machine state fits as-is;
        # the next device call recompiles against the new static app

    def _reset_slot_plane(self, slot: int):
        """Host-reset slot ``slot``'s value plane to its app's neutral —
        graph structure (edges, ghosts, rhizomes) is untouched."""
        eng, q = self.eng, slot
        init = jnp.float32(np.float32(
            self.composite.init_val[q] if self.composite.qbatch > 1
            else self.composite.init_val))
        neutral = jnp.float32(np.float32(
            self.composite.fwd_neutral[q] if self.composite.qbatch > 1
            else self.composite.fwd_neutral))
        st = eng.state
        if self.qbatch == 1:
            st = st._replace(vals=st.vals.at[..., 0].set(init),
                             fwd_val=st.fwd_val.at[...].set(neutral))
        else:
            st = st._replace(
                vals=st.vals.at[..., q].set(init),
                fwd_val=st.fwd_val.at[..., q].set(neutral),
                qchg=st.qchg.at[q].set(0),
                qlast=st.qlast.at[q].set(st.cycle))
        eng.state = st

    def _write_labels(self, slot: int):
        """CC-style admission: every vertex becomes its own source."""
        eng, cfg = self.eng, self.eng.cfg
        vids = np.arange(cfg.n_vertices, dtype=np.int64)[None, :]
        ks = np.arange(cfg.rhizome_cap, dtype=np.int64)[:, None]
        r, c, s = rhizome_rcs(cfg, vids, ks)
        labels = np.broadcast_to(vids.astype(np.float32), r.shape)
        vi = slot if self.qbatch > 1 else 0
        eng.state = eng.state._replace(
            vals=eng.state.vals.at[r, c, s, vi].set(jnp.asarray(labels)))

    def _inject_seed(self, slot: int, source: int, seed: float):
        """Push one qsel-masked OP_APP onto the action queue of the
        source's canonical-root cell (the boundary is quiescent, so the
        queue has room and no in-flight message can reorder with it)."""
        eng, cfg = self.eng, self.eng.cfg
        addr = int(root_addr(cfg, np.int64(source)))
        cell = addr // cfg.slots
        r, c = cell // cfg.width, cell % cfg.width
        WM = cfg.msg_words
        m = np.zeros(WM, np.int32)
        m[0], m[1] = OP_APP, addr
        if self.qbatch == 1:
            m[2] = np.float32(seed).view(np.int32)
        else:
            payload = np.asarray(self.composite.init_val,
                                 np.float32).copy()
            payload[slot] = seed
            bits = payload.view(np.int32)
            m[2] = bits[0]
            m[MSG_WORDS:] = bits[1:]
            m[3] = 1 << slot          # qsel: relax tenant `slot` only
        aq = np.asarray(eng.state.aq).copy()
        aq_n = np.asarray(eng.state.aq_n).copy()
        head = np.asarray(eng.state.aq_head)
        assert aq_n[r, c] < cfg.queue_cap, "action queue full at boundary?"
        tail = (head[r, c] + aq_n[r, c]) % cfg.queue_cap
        aq[r, c, tail] = m
        aq_n[r, c] += 1
        eng.state = eng.state._replace(aq=jnp.asarray(aq),
                                       aq_n=jnp.asarray(aq_n))

    # ---------------- streaming ----------------

    def run_increment(self, edges, **kw):
        """Ingest one edge increment, run to global quiescence, then fold
        the per-slot relax counters into each tenant's lifecycle."""
        edges = np.asarray(edges, np.int32).reshape(-1, 3)
        res = self.eng.run_increment(edges, **kw)
        self.edges_seen += len(edges)
        qchg = np.asarray(self.eng.state.qchg)
        qlast = np.asarray(self.eng.state.qlast)
        end_cycle = int(self.eng.state.cycle)
        for q, s in enumerate(self.slots):
            if s.state == "free":
                continue
            s.increments += 1
            if self.qbatch == 1:
                # no per-slot counters at qbatch == 1 (they are [1]
                # dummies, kept un-updated for the bit-exact trace);
                # global quiescence IS the query's quiescence, with the
                # boundary cycle as a conservative settle point
                changed = 1 if len(edges) else 0
                last = end_cycle
            else:
                changed = int(qchg[q])
                last = int(qlast[q])
            if s.state == "active" and changed == 0:
                s.state = "settled"
                s.settle_cycle = last
            elif s.state == "settled" and changed > 0:
                # the evolving graph re-activated a settled tenant; its
                # first-settle latency is already recorded
                s.state = "active"
        return res

    # ---------------- readback / retirement ----------------

    def values(self, slot: int, n: int | None = None) -> np.ndarray:
        """Per-query values: the slot's own plane, root-combined with the
        slot app's OWN reduce (min for min-monotone, max for widest)."""
        a = self.slot_apps[slot]
        return self.eng.values(n, val_idx=slot if self.qbatch > 1 else 0,
                               combine=a.combine)

    def settled_slots(self) -> "list[int]":
        return [q for q, s in enumerate(self.slots) if s.state == "settled"]

    def retire(self, slot: int, collect_values: bool = False) -> dict:
        """Free a slot for recycling; returns the tenant's receipt."""
        s = self.slots[slot]
        assert s.state != "free", f"slot {slot} already free"
        receipt = dict(slot=slot, app=s.app.name, source=s.source,
                       generation=s.generation,
                       admit_cycle=s.admit_cycle,
                       settle_cycle=s.settle_cycle,
                       latency_cycles=s.latency_cycles,
                       increments=s.increments)
        if collect_values:
            receipt["values"] = self.values(slot)
        self.slots[slot] = QuerySlot(generation=s.generation)
        return receipt
