"""The cycle engine: composes routing, execution and ingestion into one
pure ``state -> state`` step, runs it to quiescence, and exposes the
streaming-increment API used by the experiments.

Cycle order (all fixed-shape, fully vectorized over the cell grid):

  1. hop_stage      channel heads advance one link (YX DOR, backpressure)
  2. staging        active actions stage one ``propagate`` message
  3. phase0         idle cells pop one action and run its compute step
  4. io_stage       IO cells inject the next streamed edge

Quiescence (the paper's Terminator object): no queued actions, no channel
occupancy, no active action, no deferred future tasks, no pending IO.
On a real pod this is a tree all-reduce of the pending counters; here it is
literally ``jnp.sum`` inside the jitted step — GSPMD lowers it to
``all-reduce`` when the grid is sharded (see the dry-run HLO).

Two execution backends share ``cycle_body`` (DESIGN §6):

  * ``backend="jnp"`` — lax chunk runners over the HBM-resident state;
  * ``backend="pallas"`` — the fused cycle megakernel
    (``kernels/cca_cycle``): K cycles per launch with the state leaves
    held in VMEM, ``interpret=True`` fallback off-TPU.

The streaming driver's default fast path (``collect_traces=False``) runs
the whole chunk loop of an increment — including the livelock detector —
as one device-side ``lax.while_loop`` per spill pass: exactly one jit
call and one scalar readback per pass.  Per-cycle activity traces are
opt-in (``collect_traces=True``) and use the chunked host loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alloc import rhizome_rcs
from repro.core.apps import APPS, DiffusionApp
from repro.core.config import EngineConfig
from repro.core.exec_stage import phase0_stage, staging_stage
from repro.core.ingest import io_stage, load_stream
from repro.core.routing import hop_stage, park_stage
from repro.core.state import (TM_L_OCC, MachineState, init_state, root_addr,
                              self_cell_grid)
from repro.obs import frames as obs_frames


class CycleStats(NamedTuple):
    active: jax.Array      # cells doing compute/staging work this cycle
    in_flight: jax.Array   # messages sitting in channels
    backlog: jax.Array     # queued actions
    hops: jax.Array        # link traversals this cycle
    quiescent: jax.Array   # bool


def _rc(cfg: EngineConfig):
    rows = jnp.arange(cfg.height, dtype=jnp.int32)[:, None]
    cols = jnp.arange(cfg.width, dtype=jnp.int32)[None, :]
    return (jnp.broadcast_to(rows, (cfg.height, cfg.width)),
            jnp.broadcast_to(cols, (cfg.height, cfg.width)))


def quiescent(st: MachineState) -> jax.Array:
    return ((jnp.sum(st.aq_n) == 0) & (jnp.sum(st.ch_n) == 0)
            & (jnp.sum(st.pk_n) == 0)
            & ~jnp.any(st.cvalid) & (jnp.sum(st.fq_n) == 0)
            & ~jnp.any(st.fwd_pending)
            & (jnp.sum(st.io_n - st.io_pos) == 0))


def cycle_body(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    """One machine cycle, no stats reductions: hop -> staging -> phase0 ->
    io.  The single copy of the cycle semantics, shared verbatim by the
    jnp chunk runners below and the Pallas cycle megakernel
    (``kernels/cca_cycle``).  Returns the per-cell activity masks as aux
    so ``cycle_step`` can build :class:`CycleStats` without recompute
    (callers that ignore them pay nothing — XLA DCEs the masks)."""
    rows, cols = _rc(cfg)
    busy0 = st.cvalid
    if cfg.telemetry:
        # per-lane occupancy integral at cycle entry (avg depth =
        # TM_L_OCC / cycles); the other planes accumulate inside the
        # stages where the grant/stall masks live (DESIGN §8)
        st = st._replace(tm_lane=st.tm_lane.at[..., TM_L_OCC].add(st.ch_n))
    st, hops = hop_stage(cfg, st, rows, cols)
    if cfg.lanes > 1:
        # re-inject parked transit messages right after the hop stage,
        # while freshly-vacated lane slots are still free (DESIGN §7);
        # with lanes == 1 nothing ever parks — skip for a bit-exact trace
        st = park_stage(cfg, st, rows, cols)
    st, active_a = staging_stage(cfg, app, st, rows, cols)
    st, popped = phase0_stage(cfg, app, st, rows, cols, busy0)
    st = io_stage(cfg, st, rows, cols)
    if cfg.telemetry:
        hw = jnp.stack([st.aq_n, st.pk_n], axis=-1)
        st = st._replace(tm_hiw=jnp.maximum(st.tm_hiw, hw))
    st = st._replace(cycle=st.cycle + 1,
                     stat_hops=st.stat_hops + hops)
    return st, (active_a, popped, hops)


def cycle_step(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    st, (active_a, popped, hops) = cycle_body(cfg, app, st)
    stats = CycleStats(
        active=jnp.sum((active_a | popped).astype(jnp.int32)),
        in_flight=jnp.sum(st.ch_n) + jnp.sum(st.pk_n),
        backlog=jnp.sum(st.aq_n),
        hops=hops, quiescent=quiescent(st))
    return st, stats


def run_chunk_body(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    """Un-jitted fixed-length chunk (dry-run / roofline entry point: the
    caller jits this with the production-mesh shardings)."""
    def body(s, _):
        s2, _ = cycle_body(cfg, app, s)
        return s2, None
    st, _ = jax.lax.scan(body, st, None, length=cfg.chunk)
    return st


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=2)
def run_chunk(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    """Scan `cfg.chunk` cycles; freeze once quiescent (identity cycles).

    The stacked ``stats.quiescent`` records quiescence at cycle ENTRY
    (i.e. flags the frozen identity cycles), so ``argmax`` over it is
    exactly the number of cycles executed this chunk — in agreement with
    the state's own ``cycle`` counter and the sync-free device loop.
    """
    def body(s, _):
        done = quiescent(s)
        s2, stats = cycle_step(cfg, app, s)
        s = jax.tree.map(lambda a, b: jnp.where(done, a, b), s, s2)
        return s, stats._replace(quiescent=done)
    return jax.lax.scan(body, st, None, length=cfg.chunk)


def run_to_quiescence_while(cfg: EngineConfig, app: DiffusionApp,
                            st: MachineState, max_cycles=None):
    """Pure lax.while_loop runner (no traces) — the dry-run/roofline path."""
    mc = jnp.int32(max_cycles or cfg.max_cycles)
    start = st.cycle

    def cond(s):
        return (~quiescent(s)) & (s.cycle - start < mc)

    def body(s):
        s2, _ = cycle_body(cfg, app, s)
        return s2

    return jax.lax.while_loop(cond, body, st)


# Livelock detection granularity: this many consecutive chunks with zero
# executed actions while work is pending => message-dependent deadlock
# (DESIGN §4.2).  Shared by the device-side fast path and the host-side
# trace path so both backends fail identically.
LIVELOCK_CHUNKS = 8


def _livelock_msg(cfg: EngineConfig) -> str:
    return ("engine livelock: no action executed and no message hopped "
            f"for {LIVELOCK_CHUNKS * cfg.chunk} cycles with work pending "
            "— every virtual lane is stuck. "
            f"Enable virtual lanes (lanes>=2, currently {cfg.lanes}) so "
            "protocol traffic escapes head-of-line blocking, and/or "
            "increase chan_cap (>=4) / queue_cap "
            f"(>= aq_reserve+sys_reserve+8 = "
            f"{cfg.aq_reserve + cfg.sys_reserve + 8}) — see "
            "DESIGN.md §4.2/§7 buffer-sizing rules.")


class LivelockError(RuntimeError):
    """Message-dependent deadlock detected (DESIGN §4.2).

    Structured replacement for the bare ``RuntimeError`` string: carries
    the machine ``cycle`` at detection, the ``chunk`` index within the
    increment, and — when ``cfg.telemetry`` is on — the flight-recorder
    ``frames`` (:class:`repro.obs.FrameLog`; ``None`` otherwise).
    Subclasses ``RuntimeError`` with "livelock" in the message, so
    pre-existing ``except RuntimeError`` + substring handlers keep
    working without regex-parsing the message.
    """

    def __init__(self, msg: str, *, cycle: int, chunk: int, frames=None):
        super().__init__(msg)
        self.cycle = cycle
        self.chunk = chunk
        self.frames = frames


def _raise_livelock(cfg: EngineConfig, *, cycle: int, chunk: int,
                    frames=None):
    """Build and raise :class:`LivelockError`, appending the flight
    recorder's wedge report when frames were captured."""
    msg = _livelock_msg(cfg)
    if frames is not None and len(frames) >= 2:
        from repro.obs.flight import render_wedge_report
        msg = msg + "\n" + render_wedge_report(cfg, frames)
    raise LivelockError(msg, cycle=cycle, chunk=chunk, frames=frames)


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=2)
def _increment_device_loop(cfg: EngineConfig, app: DiffusionApp,
                           st: MachineState, limit):
    """One increment pass entirely on device: a ``lax.while_loop`` over
    chunks with the livelock detector folded in as a no-progress counter.

    Host<->device traffic per pass is exactly one donated state in and a
    handful of scalars out — no per-chunk ``int(stat_exec)`` syncs, no
    per-cycle stats transfer.  Each chunk either runs
    :func:`run_to_quiescence_while` capped at ``cfg.chunk`` cycles
    (backend="jnp") or one fused Pallas megakernel launch of
    ``cfg.chunk`` cycles (backend="pallas"); both leave the state frozen
    at the exact quiescence cycle, so the two backends are bit-exact.
    """
    start = st.cycle

    if cfg.backend == "pallas":
        from repro.kernels.cca_cycle.ops import cca_cycle_chunk

        def chunk(s):
            return cca_cycle_chunk(cfg, app, s)[0]
    else:
        def chunk(s):
            return run_to_quiescence_while(cfg, app, s,
                                           max_cycles=cfg.chunk)

    def cond(carry):
        s, _, noprog, _ = carry
        return ((~quiescent(s)) & (s.cycle - start < limit)
                & (noprog < LIVELOCK_CHUNKS))

    def body(carry):
        s, last_prog, noprog, ring = carry
        s = chunk(s)
        # progress = an action completed OR a message hopped a link: with
        # virtual lanes a chunk may be all-transit (messages draining
        # through sibling lanes while a hub lane is full), so exec-only
        # progress would false-positive; no-progress now means every
        # lane AND every cell is stuck (DESIGN §7)
        prog = s.stat_exec + s.stat_hops
        noprog = jnp.where(prog == last_prog, noprog + 1, jnp.int32(0))
        if cfg.telemetry:
            ring = obs_frames.ring_store(ring, obs_frames.snapshot(cfg, s))
        return (s, prog, noprog, ring)

    if cfg.telemetry:
        # frame 0 = pass baseline (also guarantees a non-empty ring even
        # for an increment that is quiescent on entry)
        ring0 = obs_frames.ring_store(obs_frames.init_ring(cfg),
                                      obs_frames.snapshot(cfg, st))
    else:
        ring0 = None  # empty pytree: rides the carry at zero cost
    st, _, noprog, ring = jax.lax.while_loop(
        cond, body, (st, st.stat_exec + st.stat_hops, jnp.int32(0), ring0))
    return st, (st.cycle - start, quiescent(st), noprog, st.stat_hops,
                st.stat_exec, st.stat_stall, st.stat_allocs), ring


@dataclasses.dataclass
class IncrementResult:
    cycles: int
    active_per_cycle: np.ndarray
    in_flight_per_cycle: np.ndarray
    hops: int
    execs: int
    stalls: int
    allocs: int
    # telemetry frame log (``cfg.telemetry=True`` only, else None): the
    # last ``cfg.frame_ring`` per-chunk frames of each spill pass, read
    # back as one batched transfer per pass (DESIGN §8)
    frames: "obs_frames.FrameLog | None" = None


class StreamingEngine:
    """Host-side driver: the accelerator-style main() of paper Listing 1."""

    def __init__(self, cfg: EngineConfig, app: str | DiffusionApp = "bfs"):
        self.cfg = cfg
        self.app = APPS[app] if isinstance(app, str) else app
        cfg = dataclasses.replace(cfg, n_vals=self.app.n_vals)
        self.cfg = cfg
        self.state = init_state(cfg, init_vals=self.app.init_val)
        self.total_cycles = 0
        self.totals = dict(hops=0, execs=0, stalls=0, allocs=0)

    # -- seeding (e.g. the BFS source vertex gets level 0 pre-stream) --
    def seed(self, vid: int, value: float, val_idx: int = 0):
        """Host-write a value into EVERY rhizome root of ``vid`` so the
        co-equal roots start value-synced (DESIGN §4.5)."""
        cfg = self.cfg
        ks = np.arange(cfg.rhizome_cap)
        r, c, s = rhizome_rcs(cfg, vid, ks)      # [R] each: one scatter
        self.state = self.state._replace(
            vals=self.state.vals.at[r, c, s, val_idx].set(value))

    # -- stream one increment of edges and run to quiescence --
    def run_increment(self, edges: np.ndarray,
                      max_cycles: int | None = None,
                      collect_traces: bool = False) -> IncrementResult:
        """Ingest ``edges`` and run to quiescence.

        ``collect_traces=False`` (default) is the sync-free fast path:
        the whole chunk loop — including the §4.2 livelock detector —
        runs device-side in one jit call per spill pass, and only scalar
        totals come back (``active_per_cycle``/``in_flight_per_cycle``
        are empty).  ``collect_traces=True`` uses the chunked host loop
        and returns the full per-cycle activity traces (jnp chunk
        runner; identical state/totals either way).
        """
        cfg = self.cfg
        limit = max_cycles or cfg.max_cycles
        self.state, spill = load_stream(cfg, self.state, edges)
        self.state = self.state._replace(stat_hops=jnp.int32(0),
                                         stat_exec=jnp.int32(0),
                                         stat_stall=jnp.int32(0),
                                         stat_allocs=jnp.int32(0))
        if cfg.telemetry:
            # the telemetry planes reset with the stat_* scalars so the
            # final frame of the increment reconciles exactly (DESIGN §8)
            self.state = self.state._replace(
                tm_cell=jnp.zeros_like(self.state.tm_cell),
                tm_lane=jnp.zeros_like(self.state.tm_lane),
                tm_hiw=jnp.zeros_like(self.state.tm_hiw))
        if collect_traces:
            return self._run_increment_traced(spill, limit)
        cycles = 0
        rings = []
        while True:
            self.state, out, ring = _increment_device_loop(
                cfg, self.app, self.state, limit - cycles)
            # exactly ONE batched transfer per pass: the scalar record
            # and the frame ring come back together
            out, ring = jax.device_get((out, ring))
            ran, q, noprog, hops, execs, stalls, allocs = \
                (int(x) for x in out)
            if ring is not None:
                rings.append(ring)
            cycles += ran
            if q and len(spill):
                # io_stream_cap overflow residue: the loaded prefix is
                # fully consumed at quiescence, so the next pass has the
                # whole IO capacity again (DESIGN §4.2)
                self.state, spill = load_stream(cfg, self.state, spill)
                continue
            break
        frames = obs_frames.FrameLog.from_rings(rings) if rings else None
        if not q and noprog >= LIVELOCK_CHUNKS:
            # Message-dependent-deadlock detector: YX DOR keeps the
            # NETWORK acyclic, but the execute stage (pop -> emit ->
            # channel) can close a protocol cycle when buffers are sized
            # below the workload's dependency depth.  Fail loudly with
            # sizing advice — and the flight recorder's wedge report when
            # telemetry is on — instead of silently dropping work.
            _raise_livelock(cfg, cycle=cycles, chunk=cycles // cfg.chunk,
                            frames=frames)
        if len(spill):
            raise RuntimeError(self._spill_msg(limit, spill))
        return self._finish_increment(
            cycles, hops, execs, stalls, allocs,
            np.zeros(0, np.int32), np.zeros(0, np.int32), frames)

    def _run_increment_traced(self, spill, limit) -> IncrementResult:
        """Chunked host loop with per-cycle activity traces (the original
        driver); used when ``collect_traces=True``."""
        cfg = self.cfg
        act, flt = [], []
        cycles = 0
        last_exec, no_progress = 0, 0
        ring = None
        if cfg.telemetry:
            # same frame schema as the device loop, snapshotted eagerly
            # per chunk (this is the debug path — syncs are fine here)
            ring = obs_frames.ring_store(obs_frames.init_ring(cfg),
                                         obs_frames.snapshot(cfg, self.state))
        while cycles < limit:
            self.state, stats = run_chunk(cfg, self.app, self.state)
            if cfg.telemetry:
                ring = obs_frames.ring_store(
                    ring, obs_frames.snapshot(cfg, self.state))
            q = np.asarray(stats.quiescent)
            a = np.asarray(stats.active)
            f = np.asarray(stats.in_flight)
            if q.any():
                n = int(np.argmax(q))  # first quiescent cycle in chunk
                act.append(a[:n]); flt.append(f[:n])
                cycles += n
                if len(spill):
                    self.state, spill = load_stream(cfg, self.state, spill)
                    continue
                break
            act.append(a); flt.append(f)
            cycles += cfg.chunk
            e = int(self.state.stat_exec) + int(self.state.stat_hops)
            no_progress = no_progress + 1 if e == last_exec else 0
            last_exec = e
            if no_progress >= LIVELOCK_CHUNKS:
                frames = (obs_frames.FrameLog.from_rings(
                    [jax.device_get(ring)]) if ring is not None else None)
                _raise_livelock(cfg, cycle=cycles,
                                chunk=cycles // cfg.chunk, frames=frames)
        if len(spill):
            raise RuntimeError(self._spill_msg(limit, spill))
        frames = (obs_frames.FrameLog.from_rings([jax.device_get(ring)])
                  if ring is not None else None)
        return self._finish_increment(
            cycles, int(self.state.stat_hops), int(self.state.stat_exec),
            int(self.state.stat_stall), int(self.state.stat_allocs),
            np.concatenate(act) if act else np.zeros(0, np.int32),
            np.concatenate(flt) if flt else np.zeros(0, np.int32), frames)

    def _spill_msg(self, limit, spill) -> str:
        # never drop work silently: the cycle limit ran out before the
        # spilled residue could be re-loaded and ingested
        return (f"cycle limit {limit} exhausted with {len(spill)} spilled "
                "edges not yet ingested; raise max_cycles or io_stream_cap "
                "(DESIGN.md §4.2).")

    def _finish_increment(self, cycles, hops, execs, stalls, allocs,
                          act, flt, frames=None) -> IncrementResult:
        self.total_cycles += cycles
        for k, v in zip(("hops", "execs", "stalls", "allocs"),
                        (hops, execs, stalls, allocs)):
            self.totals[k] += v
        return IncrementResult(
            cycles=cycles, active_per_cycle=act, in_flight_per_cycle=flt,
            hops=hops, execs=execs, stalls=stalls, allocs=allocs,
            frames=frames)

    # -- read back application values from the vertex objects --
    def values(self, n: int | None = None, val_idx: int = 0) -> np.ndarray:
        """Min-reduce over every rhizome root of each vertex.

        The canonical root always holds the tightest value (all external
        relaxes land there; siblings only receive its snapshots), so for
        the bundled monotone-min apps the reduce equals the canonical
        value — kept as a reduce so readback stays correct even mid-run.
        """
        cfg = self.cfg
        n = n or cfg.n_vertices
        # one batched gather over all (root k, vertex) pairs instead of a
        # python loop of per-k fancy indexing
        vids = np.arange(n, dtype=np.int64)[None, :]
        ks = np.arange(cfg.rhizome_cap, dtype=np.int64)[:, None]
        r, c, s = rhizome_rcs(cfg, vids, ks)                     # [R, n]
        v = np.asarray(self.state.vals[..., val_idx])[r, c, s]
        return functools.reduce(self.app.combine, v)

    def vertex_object_stats(self) -> dict:
        """Diagnostics over the hierarchical vertex objects: ghost usage +
        locality (validates Fig. 5 policies) plus rhizome fan-out and the
        spread of co-equal roots over the mesh (DESIGN §4.5)."""
        cfg = self.cfg
        st = self.state
        gs = np.asarray(st.gstate)
        ga = np.asarray(st.gaddr)
        used = int(np.sum(np.asarray(st.nfree) - cfg.primary_slots))
        out = dict(ghosts=used, mean_hops=0.0, max_hops=0,
                   rhizomes=0, multi_root_vertices=0, max_fanout=1,
                   mean_rhizome_hops=0.0)
        have = gs == 2
        if have.any():
            rr, cc, _ = np.nonzero(have)
            tgt_cell = ga[have] // cfg.slots
            tr, tc = tgt_cell // cfg.width, tgt_cell % cfg.width
            d = np.abs(rr - tr) + np.abs(cc - tc)
            out.update(mean_hops=float(d.mean()), max_hops=int(d.max()))
        if cfg.rhizome_cap > 1:
            on = np.asarray(st.rhz_on)          # [H,W,S]
            # batched gather over all (root k, vertex) pairs (no per-k
            # python loop): rows 1.. are the secondary roots
            vids = np.arange(cfg.n_vertices, dtype=np.int64)[None, :]
            ks = np.arange(cfg.rhizome_cap, dtype=np.int64)[:, None]
            r, c, s = rhizome_rcs(cfg, vids, ks)                 # [R, n]
            act = on[r, c, s][1:]                                # [R-1, n]
            fan = 1 + act.sum(axis=0)
            d = np.abs(r[1:] - r[0]) + np.abs(c[1:] - c[0])      # [R-1, n]
            out.update(
                rhizomes=int(fan.sum() - cfg.n_vertices),
                multi_root_vertices=int((fan > 1).sum()),
                max_fanout=int(fan.max()),
                mean_rhizome_hops=(float(d[act].mean())
                                   if act.any() else 0.0))
        return out
