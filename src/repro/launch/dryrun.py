import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (DESIGN §6/§7).

Lowers + compiles every (architecture x input-shape) cell against the
production meshes — (16,16) single pod and (2,16,16) multi-pod — with
ShapeDtypeStruct stand-ins (no allocation), printing memory_analysis()
and cost_analysis(), parsing the collective schedule out of the compiled
HLO, and appending everything to a JSON results file consumed by
EXPERIMENTS.md and the roofline/perf loop.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
"""
import argparse
import json
import pathlib
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_kind: str, verbose=True) -> dict:
    import jax
    from repro.configs.registry import get_shape
    from repro.dist.compat import cost_analysis_dict, use_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import (model_flops, parse_collective_bytes,
                                       roofline_terms)
    from repro.launch.steps import build_plan

    bundle, spec = get_shape(arch, shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
               n_devices=mesh.size, ok=False)
    try:
        t0 = time.time()
        plan = build_plan(bundle, spec, mesh)
        with use_mesh(mesh):
            jitted = jax.jit(plan.step, in_shardings=plan.in_shardings,
                             donate_argnums=plan.donate)
            lowered = jitted.lower(*plan.args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 2)
        mem = compiled.memory_analysis()
        print(mem)
        cost = cost_analysis_dict(compiled)
        print({k: v for k, v in cost.items()
               if k in ("flops", "bytes accessed")})
        rec["mem"] = dict(
            argument_gb=getattr(mem, "argument_size_in_bytes", 0) / 1e9,
            output_gb=getattr(mem, "output_size_in_bytes", 0) / 1e9,
            temp_gb=getattr(mem, "temp_size_in_bytes", 0) / 1e9,
            alias_gb=getattr(mem, "alias_size_in_bytes", 0) / 1e9)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        coll = parse_collective_bytes(compiled.as_text())
        rec["flops_per_dev"] = flops
        rec["bytes_per_dev"] = bytes_acc
        rec["collectives"] = coll
        # XLA's cost_analysis counts loop bodies ONCE (verified by probe):
        # for LM cells, recover exact totals by lowering L=0 and L=1
        # variants with unchunked attention and extrapolating linearly.
        if bundle.family == "lm":
            L = bundle.config.n_layers
            Tk = spec.dim("seq_len")
            c = {}
            for nl in (0, 1):
                ov = dict(n_layers=nl, attn_chunk=Tk)
                p2 = build_plan(bundle, spec, mesh, lm_overrides=ov)
                with use_mesh(mesh):
                    comp2 = jax.jit(
                        p2.step, in_shardings=p2.in_shardings,
                        donate_argnums=p2.donate).lower(*p2.args).compile()
                cost2 = cost_analysis_dict(comp2)
                coll2 = parse_collective_bytes(comp2.as_text())
                c[nl] = dict(
                    flops=float(cost2.get("flops", 0.0)),
                    bytes=float(cost2.get("bytes accessed", 0.0)),
                    coll={k: coll2[k] for k in coll2 if k != "counts"})
            flops = c[0]["flops"] + L * (c[1]["flops"] - c[0]["flops"])
            bytes_acc = c[0]["bytes"] + L * (c[1]["bytes"] - c[0]["bytes"])
            coll = {k: c[0]["coll"].get(k, 0)
                    + L * (c[1]["coll"].get(k, 0) - c[0]["coll"].get(k, 0))
                    for k in c[0]["coll"]}
            rec["flops_per_dev_true"] = flops
            rec["bytes_per_dev_true"] = bytes_acc
            rec["collectives_true"] = coll
        elif bundle.family == "cca":
            rec["note"] = ("costs are per simulated cycle x chunk counted "
                           "once = exactly one cycle per device")
        rec["roofline"] = roofline_terms(flops, bytes_acc, coll)
        mf = model_flops(bundle, spec)
        rec["model_flops_global"] = mf
        if mf == mf and flops > 0:  # not NaN
            rec["useful_ratio"] = mf / (flops * mesh.size)
        rec["desc"] = plan.static_desc
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(rec["error"])
    return rec


def merge_out(path: str, recs: list) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    data = {}
    if p.exists():
        data = json.loads(p.read_text())
    for r in recs:
        data[f'{r["arch"]}|{r["shape"]}|{r["mesh"]}'] = r
    p.write_text(json.dumps(data, indent=1, default=str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--family", help="run all archs of one family")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    from repro.configs.registry import ARCHS, cells
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        todo = cells()
    elif args.family:
        todo = [(a, s.name) for a, b in ARCHS.items()
                if b.family == args.family for s in b.shapes]
    else:
        todo = [(args.arch, args.shape)]

    done = set()
    p = pathlib.Path(args.out)
    if args.skip_done and p.exists():
        data = json.loads(p.read_text())
        done = {k for k, v in data.items() if v.get("ok")}

    for arch, shape_name in todo:
        for mk in meshes:
            if f"{arch}|{shape_name}|{mk}" in done:
                print(f"=== skip {arch} / {shape_name} / {mk} (done)")
                continue
            print(f"=== dry-run {arch} / {shape_name} / mesh={mk}")
            rec = run_cell(arch, shape_name, mk)
            merge_out(args.out, [rec])
            status = "OK" if rec["ok"] else f'FAIL {rec.get("error")}'
            print(f"=== {arch}/{shape_name}/{mk}: {status}")


if __name__ == "__main__":
    main()
