"""Multi-device CCA parity: ``run_chunk_body`` under ``cca_state_shardings``
on 8 fake host devices is BIT-EXACT with the single-device run — the
paper's single-programming-abstraction claim, end to end (subprocess like
test_partitioned_spmm: XLA device count is locked at first jax init).

Plus in-process unit tests for the repro.dist helpers.
"""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.apps import BFS
    from repro.core.config import EngineConfig
    from repro.core.engine import StreamingEngine, run_chunk_body, quiescent
    from repro.core.ingest import load_stream
    from repro.core.reference import bfs_levels
    from repro.dist.compat import AxisType, make_mesh
    from repro.dist.sharding import cca_state_shardings

    cfg = EngineConfig(height=8, width=8, n_vertices=64, ghost_slots=16,
                       io_stream_cap=256, chunk=32)
    rng = np.random.default_rng(0)
    one = np.float32(1.0).view(np.int32)
    E = 160
    edges = np.stack([rng.integers(0, 64, E), rng.integers(0, 64, E),
                      np.full(E, one)], 1).astype(np.int32)

    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    cfg = eng.cfg
    st0, spill = load_stream(cfg, eng.state, edges)
    assert len(spill) == 0
    K = 70  # 70 chunks x 32 cycles covers quiescence with slack

    f1 = jax.jit(lambda s: run_chunk_body(cfg, BFS, s))
    sA, k_run = st0, 0
    for _ in range(K):
        sA, k_run = f1(sA), k_run + 1
        if bool(quiescent(sA)):
            break
    assert bool(quiescent(sA)), "single-device run did not quiesce"

    mesh = make_mesh((4, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    shards = cca_state_shardings(mesh, jax.eval_shape(lambda: st0))
    # the mapping: cell rows over 'data', cell columns over 'model'
    from jax.sharding import PartitionSpec as P
    assert shards.vals.spec == P("data", "model", None, None)
    assert shards.aq_n.spec == P("data", "model")
    assert shards.cycle.spec == P()
    sB = jax.device_put(st0, shards)
    f8 = jax.jit(lambda s: run_chunk_body(cfg, BFS, s),
                 in_shardings=(shards,), out_shardings=shards)
    for _ in range(k_run):  # exactly as many chunks as the reference run
        sB = f8(sB)
    assert bool(quiescent(sB)), "sharded run did not quiesce"

    for name, a, b in zip(sA._fields, sA, sB):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state leaf '{name}' diverged under sharding")

    eng.state = sA
    np.testing.assert_array_equal(eng.values(),
                                  bfs_levels(cfg.n_vertices, edges, 0))
    print("CCA_PARITY_OK")
""")


def test_sharded_cca_bit_exact():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "CCA_PARITY_OK" in r.stdout, r.stdout + r.stderr


# --------------------------- in-process units ---------------------------

def test_pad_to():
    from repro.dist.sharding import pad_to
    assert pad_to(5, 4) == 8
    assert pad_to(8, 4) == 8
    assert pad_to(3, 1) == 3
    assert pad_to(0, 4) == 0


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    from repro.dist import ctx
    ctx.set_dist_mesh(None)
    x = jnp.ones((4, 6))
    assert ctx.constrain(x, "dp", "model") is x
    assert ctx.model_size() == 1
    assert ctx.dp_axes_active() == ("data",)


def test_constrain_degrades_per_dim():
    """Absent axes and indivisible dims replicate instead of erroring."""
    from repro.dist import ctx
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    # 5 not divisible by anything > 1, "pipe" absent from the mesh
    spec = ctx.resolve_spec(mesh, (5, 8), ("pipe", "model"))
    assert spec[0] is None
    ctx.set_dist_mesh(mesh)
    try:
        import jax.numpy as jnp
        y = ctx.constrain(jnp.ones((4, 4)), "dp", "model")
        assert y.shape == (4, 4)
    finally:
        ctx.set_dist_mesh(None)


def test_split_stages_shapes():
    import jax.numpy as jnp
    import pytest
    from repro.dist.pipeline import split_stages
    p = dict(w=jnp.arange(8 * 3 * 3, dtype=jnp.float32).reshape(8, 3, 3),
             b=jnp.arange(8.0).reshape(8))
    s = split_stages(p, 4)
    assert s["w"].shape == (4, 2, 3, 3) and s["b"].shape == (4, 2)
    with pytest.raises(ValueError):
        split_stages(p, 3)


def test_pipelined_apply_sequential_fallback():
    """Without a pipe axis, pipelined_apply == the plain sequential net."""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import pipelined_apply, split_stages
    L, D = 4, 8
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (L, D, D)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1

    def stage_fn(p, x):
        def body(x, lp):
            return jnp.tanh(x @ lp["w"] + lp["b"]), None
        x, _ = jax.lax.scan(body, x, p)
        return x

    xs = jax.random.normal(jax.random.PRNGKey(2), (3, 5, D))
    got = pipelined_apply(stage_fn, split_stages(dict(w=w, b=b), 2),
                          xs, mesh=None)

    def ref_one(x):
        for l in range(L):
            x = jnp.tanh(x @ w[l] + b[l])
        return x
    want = jax.vmap(ref_one)(xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_cca_state_sharding_rules():
    """Every leaf gets a sharding; on a 1-device mesh all replicate
    (size-1 axes degrade to None — exact tiling is asserted on the real
    8-device mesh inside the subprocess above)."""
    import functools
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.config import EngineConfig
    from repro.core.state import init_state
    from repro.dist.sharding import cca_state_shardings
    from repro.launch.mesh import make_host_mesh
    cfg = EngineConfig(height=8, width=8, n_vertices=64, ghost_slots=16,
                       io_stream_cap=256, chunk=8)
    shape = jax.eval_shape(functools.partial(init_state, cfg))
    sh = cca_state_shardings(make_host_mesh(1, 1), shape)
    assert all(isinstance(s, NamedSharding) for s in jax.tree.leaves(sh))
    assert sh.cycle.spec == P()
    assert all(e is None for e in sh.vals.spec)  # size-1 axes -> replicated
