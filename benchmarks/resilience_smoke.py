"""Resilience smokes + recovery-path cost profile (DESIGN §9) ->
``results/bench_engine.json``.

Three gates, all exactness-based (the CI ``fault-smoke`` job runs them
on both backends):

  * **fault_smoke** — a ci-scale BFS stream under a seeded drop+blackout
    ``FaultPlan`` must demonstrably lose messages (``flt`` counters > 0)
    and STILL converge to the NetworkX-exact values via the
    detection+repair pass;
  * **kill_resume_smoke** — checkpoint at an increment boundary, discard
    the engine, restore, replay the tail: every state leaf bit-equal to
    the uninterrupted run;
  * **recovery_smoke** — the known lanes=1 hub wedge (DESIGN §4.2/§7)
    completes via ``RecoveryPolicy`` escalation, with the attempt log
    recording the wedge report.

``profile_resilience`` records what the robustness layer costs when
nothing goes wrong: a checkpoint-cadence sweep (save every increment /
every other / never) and the faults-off vs zero-rate-plan vs faulty
wall-clock deltas.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.engine_throughput import ENGINE_SCALES, _cfg, _merge
from repro.core import StreamingEngine
from repro.core.reference import bfs_levels
from repro.graph.streams import StreamSpec, make_stream
from repro.resilience import FaultPlan, RecoveryPolicy
from repro.train.checkpoint import Checkpointer

BACKENDS = ("jnp", "pallas")


def _stream(p: dict, increments: int = 3):
    spec = StreamSpec(n_vertices=p["n_vertices"], n_edges=p["n_edges"],
                      increments=increments, sampling="edge", seed=3)
    incs = make_stream(spec)
    want = bfs_levels(p["n_vertices"], np.concatenate(incs), 0)
    return incs, want


def fault_smoke(scale: str = "ci") -> dict:
    """Seeded drop+blackout+corrupt stream converges exact via repair."""
    p = ENGINE_SCALES.get(scale, ENGINE_SCALES["ci"])
    incs, want = _stream(p)
    plan = FaultPlan(seed=7, drop_rate=0.04, dup_rate=0.02,
                     corrupt_rate=0.02,
                     blackouts=((0, 1, 2, 0, p["chunk"]),))
    rec = {}
    for backend in BACKENDS:
        eng = StreamingEngine(
            _cfg(p, backend, faults=plan, telemetry=True), "bfs")
        eng.seed(0, 0.0)
        t0 = time.time()
        cycles, flt = 0, np.zeros(4, np.int64)
        for inc in incs:
            cycles += eng.run_increment(inc, max_cycles=2_000_000).cycles
            flt += np.asarray(eng.state.flt)  # counters reset per increment
        lost = int(flt[0]) + int(flt[2])
        assert lost > 0, \
            f"fault plan injected nothing on backend={backend}: {flt}"
        np.testing.assert_array_equal(eng.values(p["n_vertices"]), want)
        rec[backend] = dict(status="exact-after-repair", cycles=cycles,
                            wall_s=round(time.time() - t0, 3),
                            dropped=int(flt[0]), duplicated=int(flt[1]),
                            corrupted=int(flt[2]), blackout_hits=int(flt[3]))
    return rec


def kill_resume_smoke(scale: str = "ci") -> dict:
    """Kill after increment 2 of 3, restore, replay: bit-exact finals."""
    p = ENGINE_SCALES.get(scale, ENGINE_SCALES["ci"])
    incs, want = _stream(p)
    rec = {}
    for backend in BACKENDS:
        cfg = _cfg(p, backend)
        ref = StreamingEngine(cfg, "bfs")
        ref.seed(0, 0.0)
        for inc in incs:
            ref.run_increment(inc, max_cycles=2_000_000)
        with tempfile.TemporaryDirectory() as d:
            eng = StreamingEngine(cfg, "bfs")
            eng.seed(0, 0.0)
            ck = Checkpointer(d)
            for inc in incs[:2]:
                eng.run_increment(inc, ckpt=ck, max_cycles=2_000_000)
            eng.checkpoint(ck)
            del eng                                   # the "kill"
            res = StreamingEngine.restore(cfg, "bfs", Checkpointer(d))
            res.run_increment(incs[2], max_cycles=2_000_000)
            for name, a, b in zip(res.state._fields, res.state, ref.state):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"leaf '{name}' diverged across kill-and-resume"
                            f" on backend={backend}")
            np.testing.assert_array_equal(res.values(p["n_vertices"]), want)
        rec[backend] = dict(status="bit-exact", resumed_at=2,
                            totals=dict(res.totals))
    return rec


def recovery_smoke() -> dict:
    """The pinned lanes=1 hub wedge completes via lanes escalation."""
    from repro.core import EngineConfig
    from repro.graph.streams import hub_edges
    one = np.float32(1.0).view(np.int32)
    e = hub_edges(128, 0, 200, seed=3)
    edges = np.concatenate([e, np.full((len(e), 1), one, np.int64)],
                           1).astype(np.int32)
    cfg = EngineConfig(height=8, width=8, n_vertices=128, edge_cap=4,
                       ghost_slots=48, queue_cap=20, chan_cap=16,
                       futq_cap=4, chunk=64, lanes=1, max_cycles=200_000,
                       telemetry=True)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    eng.run_increment(edges, recover=RecoveryPolicy(max_attempts=2))
    np.testing.assert_array_equal(
        eng.values(), bfs_levels(128, e, source=0))
    assert eng.cfg.lanes == 2 and len(eng.recovery_log) == 1
    return dict(status="recovered", escalated_lanes=eng.cfg.lanes,
                attempts=len(eng.recovery_log),
                wedge_cycle=eng.recovery_log[0]["cycle"])


def profile_resilience(scale: str = "ci", backend: str = "jnp") -> dict:
    """Cost of the robustness layer on the happy path: checkpoint-cadence
    sweep + faults-off vs zero-rate-plan vs live-faults deltas."""
    p = ENGINE_SCALES.get(scale, ENGINE_SCALES["ci"])
    incs, _ = _stream(p, increments=4)

    def run(ck_every=0, faults=None, telemetry=False):
        eng = StreamingEngine(
            _cfg(p, backend, faults=faults, telemetry=telemetry), "bfs")
        eng.seed(0, 0.0)
        eng.run_increment(incs[0], max_cycles=2_000_000)  # warm the jit
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            t0 = time.time()
            for i, inc in enumerate(incs[1:]):
                use = ck_every and (i % ck_every == 0)
                eng.run_increment(inc, max_cycles=2_000_000,
                                  ckpt=ck if use else None)
            ck.wait()
            return round(time.time() - t0, 3)

    base = run()
    rec = dict(backend=backend, increments=len(incs) - 1,
               baseline_wall_s=base)
    # checkpoint cadence sweep: async boundary saves overlap the device
    # loop, so the cadence cost is the residual serialization tail
    for every, name in ((1, "ckpt_every_1"), (2, "ckpt_every_2")):
        w = run(ck_every=every)
        rec[name] = dict(wall_s=w,
                         overhead_pct=round(100 * (w - base) / base, 1))
    # fault machinery cost: zero-rate plan traces the fault code but
    # fires nothing; the live plan adds the repair pass on top
    for plan, name in ((FaultPlan(seed=7), "faults_zero_rate"),
                       (FaultPlan(seed=7, drop_rate=0.04,
                                  corrupt_rate=0.02), "faults_live")):
        w = run(faults=plan, telemetry=True)
        rec[name] = dict(wall_s=w,
                         overhead_pct=round(100 * (w - base) / base, 1))
    return rec


def bench_resilience(scale: str = "ci", profile: bool = False) -> dict:
    rec = dict(scale=scale, fault_smoke=fault_smoke(scale),
               kill_resume=kill_resume_smoke(scale),
               recovery=recovery_smoke())
    if profile:
        rec["profile"] = profile_resilience(scale)
    _merge(rec, key=f"resilience_{scale}")
    return rec


if __name__ == "__main__":
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="ci", choices=list(ENGINE_SCALES))
    ap.add_argument("--profile", action="store_true")
    args = ap.parse_args()
    print(json.dumps(bench_resilience(args.scale, profile=args.profile),
                     indent=1))
