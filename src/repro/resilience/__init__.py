"""Resilience: durable streaming state, deterministic fault injection,
detection + graceful degradation (DESIGN §9).

Three layers, all riding the existing machinery:

* **Durable state** — ``StreamingEngine.checkpoint/restore`` route the
  ``MachineState`` pytree + stream cursor + config fingerprint through
  ``train/checkpoint.Checkpointer`` at increment boundaries
  (:mod:`repro.resilience.checkpoint`).
* **Fault injection** — a seeded, static :class:`FaultPlan` applied
  inside ``cycle_body`` (drop / blackout / duplicate / corrupt), with
  message seals and the ``flt`` counter leaf
  (:mod:`repro.resilience.faults`).
* **Detection + degradation** — the §8 conservation invariants as an
  end-of-increment loss detector driving a bounded ``OP_REPAIR`` pass;
  :class:`RecoveryPolicy` escalation on livelock with boundary-state
  migration (:mod:`repro.resilience.recover`); ``tm_hiw``-gated ingest
  admission.
"""
from repro.resilience.checkpoint import (CKPT_KIND, config_fingerprint,
                                         stream_manifest)
from repro.resilience.faults import (FLT_BLACKOUT, FLT_CORRUPT, FLT_DROP,
                                     FLT_DUP, N_FLT, FaultPlan, fault_hash16,
                                     is_droppable)
from repro.resilience.recover import (STORAGE_LEAVES, RecoveryPolicy,
                                      assert_boundary, migrate_state)

__all__ = [
    "CKPT_KIND", "FLT_BLACKOUT", "FLT_CORRUPT", "FLT_DROP", "FLT_DUP",
    "FaultPlan", "N_FLT", "RecoveryPolicy", "STORAGE_LEAVES",
    "assert_boundary", "config_fingerprint", "fault_hash16",
    "is_droppable", "migrate_state", "stream_manifest",
]
