"""Batched serving demo: continuous-batching decode loop with ragged
per-slot cache lengths.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve
from repro.launch.train import PRESETS

tokens, tput = serve(PRESETS["lm_tiny"], n_requests=6, batch=3,
                     prompt_len=8, gen_len=8, max_len=64)
assert all(len(v) > 0 for v in tokens.values())
print(f"served {len(tokens)} requests at {tput:.1f} tok/s aggregate")
