"""Shared model building blocks (pure JAX, framework-free)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def mlp_init(key, sizes, dtype=jnp.float32):
    """Plain MLP params: list of (W, b)."""
    ks = jax.random.split(key, len(sizes) - 1)
    return [
        dict(w=dense_init(ks[i], (sizes[i], sizes[i + 1]), dtype=dtype),
             b=jnp.zeros((sizes[i + 1],), dtype))
        for i in range(len(sizes) - 1)
    ]


def mlp_apply(params, x, act=jax.nn.relu, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"].astype(x.dtype) + lyr["b"].astype(x.dtype)
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def count_params(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))
