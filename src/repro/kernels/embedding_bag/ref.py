"""Oracle for EmbeddingBag: gather + bag reduce."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, indices, combiner="sum"):
    """table: [V, D]; indices: [B, L] -> [B, D]."""
    rows = jnp.take(table, indices, axis=0)
    out = rows.sum(axis=1)
    if combiner == "mean":
        out = out / indices.shape[1]
    return out
