"""Oracle for the scatter-SpMM: plain segment_sum."""
from __future__ import annotations

import jax


def scatter_spmm_ref(msgs, dst, n_nodes):
    """msgs: [E, D]; dst: [E] -> [N, D] summed by destination."""
    return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
