"""Process-global distribution context: one mesh, one constraint helper.

The paper's vertex object is "parallelized across many scratchpad
memory-coupled cores and yet provides a single programming abstraction to
the data object" — here the single abstraction is the model/engine code
written against plain arrays, and this module is the thin seam through
which GSPMD distributes them.  Model code never talks to a mesh directly:
it calls ``constrain(x, *axes)`` with logical axis names and the call
degrades to identity when no mesh is registered (single-process tests) or
when the named axes do not exist / do not divide the dimension.

Logical axis vocabulary (DESIGN §5):

* ``"model"``          — tensor-parallel axis,
* ``"data"`` / ``"pod"`` — data-parallel axes (``"pod"`` only on
  multi-pod meshes; gradient reduction is hierarchical),
* ``"dp"``             — alias expanding to the active data-parallel axis
  group (``("pod", "data")`` or ``("data",)``),
* ``None``             — replicated dimension,
* a tuple of names     — dimension sharded over several mesh axes.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat  # noqa: F401  (installs the jax API shims)

_DIST_MESH = None


def set_dist_mesh(mesh):
    """Register the process mesh used by ``constrain`` (None to clear)."""
    global _DIST_MESH
    _DIST_MESH = mesh
    return mesh


def get_dist_mesh():
    return _DIST_MESH


def model_size(mesh=None) -> int:
    """Size of the tensor-parallel ('model') axis; 1 when unmeshed."""
    mesh = mesh if mesh is not None else _DIST_MESH
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def dp_axes_active(mesh=None) -> tuple:
    """The data-parallel axis group present on the mesh.

    ``("pod", "data")`` on multi-pod meshes, ``("data",)`` otherwise;
    defaults to ``("data",)`` when no mesh is registered so callers can
    build PartitionSpecs unconditionally.
    """
    mesh = mesh if mesh is not None else _DIST_MESH
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names) \
        or ("data",)


def _resolve_entry(mesh, entry):
    """One PartitionSpec entry -> tuple of valid mesh axis names (or ())."""
    if entry is None:
        return ()
    if isinstance(entry, str):
        names = dp_axes_active(mesh) if entry == "dp" else (entry,)
    else:  # tuple/list of axis names (possibly containing "dp")
        names = []
        for e in entry:
            names.extend(dp_axes_active(mesh) if e == "dp" else (e,))
        names = tuple(names)
    return tuple(n for n in names if n in mesh.axis_names)


def resolve_spec(mesh, shape, axes) -> P:
    """Logical axes -> a PartitionSpec valid for ``shape`` on ``mesh``.

    Per-dimension no-op (-> replicated) when the named axes are absent
    from the mesh or their combined size does not divide the dimension.
    """
    spec = []
    for dim, entry in zip(shape, axes):
        names = _resolve_entry(mesh, entry)
        size = int(np.prod([mesh.shape[n] for n in names])) if names else 1
        if not names or size <= 1 or dim % size != 0:
            spec.append(None)
        elif len(names) == 1:
            spec.append(names[0])
        else:
            spec.append(tuple(names))
    return P(*spec)


def constrain(x, *axes):
    """Sharding-constrain ``x`` onto the registered mesh (identity when
    unmeshed, axes absent, or sizes indivisible).  ``len(axes)`` must
    equal ``x.ndim``."""
    mesh = _DIST_MESH
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(
            f"constrain: got {len(axes)} axes for rank-{x.ndim} array")
    spec = resolve_spec(mesh, x.shape, axes)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
