"""Vectorized fixed-capacity ring buffers over the cell grid.

Every queue in the machine (action queues, channel buffers, future queues)
is a ring buffer with leading batch dims (e.g. ``[H, W]`` or ``[H, W, S]``),
a capacity axis, and a trailing message-word axis.

Implementation note (§Perf, cca cell): pushes/pops are **one-hot
`where` ops, not scatters/gathers**.  GSPMD partitions elementwise ops
over the sharded cell grid trivially, whereas scatters with index arrays
were being partitioned with per-cycle all-gathers of the updates (found
in the chip_512x512 HLO audit).  On CPU the one-hot form is also faster:
XLA vectorizes the compare+select, while scatter serializes.
"""
from __future__ import annotations

import jax.numpy as jnp


def _iota(cap, dtype=jnp.int32):
    return jnp.arange(cap, dtype=dtype)


def ring_push(buf, cnt, head, msg, mask):
    """Masked push.  buf: [*B, CAP, W]; cnt/head/mask: [*B]; msg: [*B, W].

    Caller must guarantee ``cnt < CAP`` wherever ``mask`` is True.
    """
    cap = buf.shape[-2]
    tail = (head + cnt) % cap
    oh = (_iota(cap) == tail[..., None]) & mask[..., None]     # [*B, CAP]
    buf = jnp.where(oh[..., None], msg[..., None, :], buf)
    cnt = cnt + mask.astype(cnt.dtype)
    return buf, cnt


def ring_peek(buf, head):
    """Read head element.  Returns [*B, W] (zeros where empty)."""
    cap = buf.shape[-2]
    oh = _iota(cap) == (head % cap)[..., None]                 # [*B, CAP]
    return jnp.sum(jnp.where(oh[..., None], buf, 0), axis=-2)


def ring_pop(cnt, head, cap, mask):
    """Advance head (element itself read via ring_peek)."""
    m = mask.astype(cnt.dtype)
    return cnt - m, (head + m) % cap


def ring_free(cnt, cap, reserve=0):
    return cnt < (cap - reserve)
