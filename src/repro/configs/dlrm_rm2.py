"""--arch dlrm-rm2 (exact published config; see recsys_archs.py)."""
from repro.configs.recsys_archs import DLRM_RM2 as CONFIG
from repro.configs.registry import get

BUNDLE = get("dlrm-rm2")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
