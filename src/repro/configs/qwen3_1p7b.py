"""--arch qwen3-1.7b (exact published config; see lm_archs.py)."""
from repro.configs.lm_archs import QWEN3_1P7B as CONFIG
from repro.configs.registry import get

BUNDLE = get("qwen3-1.7b")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
