"""Config/shape registry plumbing.

Every assigned architecture contributes an ArchBundle: the exact published
configuration, its shape set (each (arch x shape) cell is a dry-run +
roofline row), and a reduced smoke config runnable on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # lm_train | lm_prefill | lm_decode |
                       # gnn_full | gnn_minibatch | gnn_batched |
                       # recsys_train | recsys_serve | recsys_retrieval |
                       # cca_stream
    dims: tuple        # sorted (key, value) pairs

    def dim(self, k, default=None):
        return dict(self.dims).get(k, default)


def shape(name, kind, **dims) -> ShapeSpec:
    return ShapeSpec(name=name, kind=kind, dims=tuple(sorted(dims.items())))


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    arch_id: str
    family: str        # lm | gnn | recsys | cca
    config: Any
    shapes: tuple
    smoke: Callable    # () -> reduced config (same family)
    notes: str = ""


# ---- the common LM shape set (assigned to all 5 LM archs) ----

def lm_shapes():
    return (
        shape("train_4k", "lm_train", seq_len=4096, global_batch=256),
        shape("prefill_32k", "lm_prefill", seq_len=32768, global_batch=32),
        shape("decode_32k", "lm_decode", seq_len=32768, global_batch=128),
        # decode against a 512k KV cache is LINEAR in seq_len (one query):
        # we run it with the cache sequence-sharded (flash-decoding style).
        # Pool guidance says skip for pure full-attention archs; see
        # DESIGN.md §5 for why the decode cell is still well-defined & run.
        shape("long_500k", "lm_decode", seq_len=524288, global_batch=1),
    )


def gnn_shapes():
    return (
        shape("full_graph_sm", "gnn_full", n_nodes=2708, n_edges=10556,
              d_feat=1433),
        shape("minibatch_lg", "gnn_minibatch", n_nodes=232965,
              n_edges=114615892, batch_nodes=1024, fanout=(15, 10),
              d_feat=602),
        shape("ogb_products", "gnn_full", n_nodes=2449029, n_edges=61859140,
              d_feat=100),
        shape("molecule", "gnn_batched", n_nodes=30, n_edges=64, batch=128,
              d_feat=32),
    )


def recsys_shapes():
    return (
        shape("train_batch", "recsys_train", batch=65536),
        shape("serve_p99", "recsys_serve", batch=512),
        shape("serve_bulk", "recsys_serve", batch=262144),
        shape("retrieval_cand", "recsys_retrieval", batch=1,
              n_candidates=1_000_000),
    )
