"""Core: the paper's message-driven streaming dynamic graph engine."""
from repro.core.apps import APPS, BFS, CC, INGEST_ONLY, SSSP, DiffusionApp
from repro.core.config import EngineConfig
from repro.core.engine import (LIVELOCK_CHUNKS, IncrementResult,
                               StreamingEngine, cycle_body, cycle_step,
                               quiescent, run_chunk,
                               run_to_quiescence_while)
from repro.core.state import MachineState, init_state, root_addr

__all__ = [
    "APPS", "BFS", "CC", "INGEST_ONLY", "SSSP", "DiffusionApp",
    "EngineConfig", "IncrementResult", "LIVELOCK_CHUNKS", "StreamingEngine",
    "MachineState", "cycle_body", "cycle_step", "quiescent", "run_chunk",
    "run_to_quiescence_while", "init_state", "root_addr",
]
