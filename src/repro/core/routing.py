"""YX dimension-ordered routing on the cell mesh (paper §4) with
virtual-lane flow control on the physical links (DESIGN §7).

Messages take vertical (row) hops first, then horizontal — the
turn-restricted, minimal-path, deadlock-free YX variant of [Glass & Ni'92]
cited by the paper.  One hop per cycle per link (256-bit flit).

Each physical link multiplexes ``cfg.lanes`` independently-queued
**virtual lanes** (Dally-style VC flow control): lane 0 is the escape
lane reserved for protocol/continuation traffic (allocate, set-future,
link-rhizome and the rhizome link-ack), lanes ``1..lanes-1`` carry
application traffic hashed by destination (:func:`msg_lane`).  A
round-robin arbiter at every link grants the flit slot to one admissible
lane per cycle, so a lane wedged behind a congested hub can never block
its sibling lanes — the seed-era head-of-line deadlock of DESIGN §4.2.
With ``cfg.lanes == 1`` every message rides lane 0 and the machine is
bit-exact with the pre-lane engine.

The hop stage is written as masked ``jnp.roll`` over the ``[H, W]`` grid.
Under pjit/GSPMD with the grid sharded over mesh axes this lowers to
``collective-permute`` at tile boundaries — the TPU ICI plays the role of
the AM-CCA mesh links (DESIGN §2).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import EngineConfig
from repro.core.msg import (DIR_E, DIR_N, DIR_S, DIR_W, N_DIRS,
                            OP_ALLOC, OP_LINK_RHIZOME, OP_RHIZOME_FWD,
                            OP_SET_FUTURE, TB_AQ_SELF, TB_CHAN_E, TB_CHAN_N,
                            TB_CHAN_S, TB_CHAN_W)
from repro.core import rings
from repro.core.state import (MachineState, TM_HOP, TM_L_BLOCK, TM_L_GRANT,
                              TM_UNPARK)


def is_protocol(op):
    """``True`` where ``op`` is a system/continuation opcode.

    These are the messages that *unblock* deferred work (Fig. 3/4 and the
    §4.5 rhizome link protocol): ``OP_ALLOC``, ``OP_SET_FUTURE``,
    ``OP_LINK_RHIZOME`` and the ``OP_RHIZOME_FWD`` link-ack.  They get
    two privileges the application traffic does not:

    * the deeper ``aq_reserve``-only admission bound at the action queue
      (application pushes stop ``sys_reserve`` earlier — DESIGN §4.2);
    * the **escape lane** (lane 0) on every physical link, which the
      round-robin arbiter serves independently of the application lanes
      (DESIGN §7), so a continuation can always reach a queue that still
      has protocol headroom.

    Shapes broadcast; returns a boolean array shaped like ``op``.
    """
    return ((op == OP_ALLOC) | (op == OP_SET_FUTURE)
            | (op == OP_LINK_RHIZOME) | (op == OP_RHIZOME_FWD))


def msg_lane(cfg: EngineConfig, op, dst):
    """Virtual-lane assignment of a message: ``lane = f(op, dst)``.

    Protocol/continuation opcodes (:func:`is_protocol`) ride the reserved
    **escape lane 0**; application messages (insert-edge, app relax) hash
    their destination address onto the data lanes ``1..cfg.lanes-1`` so
    streams converging on different vertices occupy different FIFOs.  The
    lane is a pure function of the message, so it is identical at every
    hop — a message stays in its lane end-to-end and any cell can compute
    any message's lane locally (no per-link lane state to carry).

    With ``cfg.lanes == 1`` everything maps to lane 0 (the pre-lane
    single-FIFO channel).  Shapes broadcast; returns int32 lane ids.
    """
    dst = jnp.asarray(dst, jnp.int32)
    if cfg.lanes == 1:
        return jnp.zeros(jnp.broadcast_shapes(jnp.shape(op), dst.shape),
                         jnp.int32)
    data = 1 + dst % jnp.int32(cfg.lanes - 1)
    return jnp.where(is_protocol(op), jnp.int32(0), data)


def manhattan_hops(cfg: EngineConfig, dst_cell, rows, cols):
    """YX-DOR path length (Manhattan hops) from cell ``(rows, cols)`` to
    ``dst_cell``.

    Shapes broadcast.  This is the routing-distance metric used by IO
    cells to pick the *nearest* rhizome root of a vertex (DESIGN §4.5).
    """
    dr = dst_cell // cfg.width
    dc = dst_cell % cfg.width
    return jnp.abs(dr - rows) + jnp.abs(dc - cols)


def yx_target_buffer(cfg: EngineConfig, dst_cell, rows, cols):
    """Next-buffer code for a message sitting at cell ``(rows, cols)``.

    Vertical first, then horizontal, deliver locally when arrived:
    returns ``TB_CHAN_N/S`` while the row differs, ``TB_CHAN_W/E`` while
    only the column differs, and ``TB_AQ_SELF`` on arrival.  Shapes
    broadcast; returns int32 target-buffer codes (``TB_*``).
    """
    dr = dst_cell // cfg.width
    dc = dst_cell % cfg.width
    vert = jnp.where(dr < rows, TB_CHAN_N, TB_CHAN_S)
    horiz = jnp.where(dc < cols, TB_CHAN_W, TB_CHAN_E)
    out = jnp.where(dr != rows, vert, jnp.where(dc != cols, horiz, TB_AQ_SELF))
    return out.astype(jnp.int32)


def deliver(cfg: EngineConfig, aq, aq_n, aq_head, ch, ch_n, ch_head,
            msg, tb, lane, want, aq_room):
    """Shape-polymorphic buffer admission: place ``msg`` into the local
    action queue (``tb == TB_AQ_SELF``) or lane ``lane`` of one of the
    four outgoing channels (``tb == TB_CHAN_*``) of the cell it currently
    sits at.

    All operands share arbitrary leading batch dims ``*B`` — the full
    ``[H, W]`` grid in the hop/staging stages (jnp path and the Pallas
    cycle megakernel alike), the ``[W]`` row-0 slice in the IO stage::

        aq [*B,Q,MSG]  aq_n/aq_head [*B]   ch [*B,4,L,LC,MSG]
        ch_n/ch_head [*B,4,L]  msg [*B,MSG]  tb/lane/want/aq_room [*B]

    **Reserve-predicate contract.**  ``aq_room`` is the caller's
    action-queue admission predicate; ``deliver`` applies it verbatim and
    adds nothing.  Every stage supplies a different reserve rule
    (DESIGN §4.2):

    * *hop stage*: ``ring_free(aq_n, Q, aq_reserve)`` for protocol
      messages, ``ring_free(aq_n, Q, aq_reserve + sys_reserve)`` for
      application messages — external pushes must leave the active
      action's local-emission slots plus the system headroom free;
    * *IO stage*: the application rule (injected inserts are app
      traffic);
    * *staging stage*: plain ``ring_free(aq_n, Q)`` — **local**
      emissions are entitled to the reserved region, which is what makes
      an action unable to wedge on its own queue.

    Channel admission is per-lane: ``ring_free`` of the target lane's
    ring against ``cfg.lane_capacity`` (no reserves — the escape-lane
    split is the channels' progress guarantee, DESIGN §7).  ``lane`` must
    equal ``msg_lane(cfg, msg)`` for routed messages; the hop stage
    passes the in-transit lane through unchanged.

    Returns ``(aq, aq_n, ch, ch_n, ok)`` — the updated buffers and the
    acceptance mask.  Where ``want & ~ok`` the message stays with the
    caller (wormhole-style backpressure stall); ``deliver`` never drops
    a message.
    """
    ok_aq = want & (tb == TB_AQ_SELF) & aq_room
    aq, aq_n = rings.ring_push(aq, aq_n, aq_head, msg, ok_aq)
    ok_all = ok_aq
    L, LC = cfg.lanes, cfg.lane_capacity
    oh_lane = rings._iota(L) == lane[..., None]                # [*B, L]
    # width-polymorphic over the record length (cfg.msg_words: 5 classic
    # words + qbatch-1 payload extension words, DESIGN §10)
    msg_l = jnp.broadcast_to(msg[..., None, :],
                             msg.shape[:-1] + (L, msg.shape[-1]))
    for d in range(N_DIRS):
        ok = ((want & (tb == d))[..., None] & oh_lane
              & rings.ring_free(ch_n[..., d, :], LC))          # [*B, L]
        nb, nn = rings.ring_push(ch[..., d, :, :, :], ch_n[..., d, :],
                                 ch_head[..., d, :], msg_l, ok)
        ch = ch.at[..., d, :, :, :].set(nb)
        ch_n = ch_n.at[..., d, :].set(nn)
        ok_all = ok_all | jnp.any(ok, axis=-1)
    return aq, aq_n, ch, ch_n, ok_all


def park_stage(cfg: EngineConfig, st: MachineState, rows, cols):
    """Drain the per-cell park buffers back into the virtual lanes
    (DESIGN §7; ``lanes > 1`` only — callers skip it otherwise).

    A remote emission whose channel lane was full at staging time was
    *parked* (``exec_stage.staging_stage``) instead of wedging the cell's
    execute pipeline.  Every cycle this stage attempts to re-inject each
    cell's park-buffer head into its YX next lane; on failure the head
    rotates to the tail so one blocked transit cannot head-of-line block
    the rest of the buffer.  The port is independent of the cell's
    action/staging registers — parked traffic drains even while the cell
    is busy computing, which is half of the §7 consumption guarantee
    (the other half being that parked messages never occupy action-queue
    space and so never hold the queue above its admission thresholds).
    """
    PK = cfg.park_capacity
    head = rings.ring_peek(st.pk, st.pk_head)                  # [H,W,MSG]
    want = st.pk_n > 0
    tb = yx_target_buffer(cfg, head[..., 1] // cfg.slots, rows, cols)
    lane = msg_lane(cfg, head[..., 0], head[..., 1])
    # dst is remote by construction (parking requires tb != TB_AQ_SELF at
    # park time and parked messages re-check their tb here each cycle —
    # aq_room=False keeps even a stale local-looking head out of the AQ)
    aq, aq_n, ch, ch_n, ok = deliver(
        cfg, st.aq, st.aq_n, st.aq_head, st.ch, st.ch_n, st.ch_head,
        head, tb, lane, want, jnp.zeros_like(want))
    # success: pop.  failure: rotate (head -> tail; net ring size kept)
    fail = want & ~ok
    tail = (st.pk_head + st.pk_n) % PK
    oh = (rings._iota(PK) == tail[..., None]) & fail[..., None]
    pk = jnp.where(oh[..., None], head[..., None, :], st.pk)
    pk_n = st.pk_n - ok.astype(jnp.int32)
    pk_head = (st.pk_head + want.astype(jnp.int32)) % PK
    st = st._replace(aq=aq, aq_n=aq_n, ch=ch, ch_n=ch_n,
                     pk=pk, pk_n=pk_n, pk_head=pk_head)
    if cfg.telemetry:
        st = st._replace(tm_cell=st.tm_cell.at[..., TM_UNPARK]
                         .add(ok.astype(jnp.int32)))
    return st


# direction -> (row shift, col shift) that moves a message ALONG d.
_SHIFT = {DIR_N: (-1, 0), DIR_S: (1, 0), DIR_W: (0, -1), DIR_E: (0, 1)}


def shift_to_receiver(arr, d):
    """Move per-sender values ``[H, W, ...]`` so they align with the
    receiving cell of a hop along direction ``d``.

    A message leaving ``(r, c)`` northwards arrives at ``(r-1, c)``:
    roll by ``-1`` on rows.  Mesh (non-torus): wrapped entries are masked
    by the caller using :func:`valid_receiver_mask`.
    """
    dy, dx = _SHIFT[d]
    a = arr
    if dy:
        a = jnp.roll(a, dy, axis=0)
    if dx:
        a = jnp.roll(a, dx, axis=1)
    return a


def shift_to_sender(arr, d):
    """Inverse of :func:`shift_to_receiver`: align per-receiver values
    (e.g. the acceptance mask) back onto the sending cell."""
    dy, dx = _SHIFT[d]
    a = arr
    if dy:
        a = jnp.roll(a, -dy, axis=0)
    if dx:
        a = jnp.roll(a, -dx, axis=1)
    return a


def valid_receiver_mask(cfg: EngineConfig, d):
    """``[H, W]`` bool: True where a received-from-direction-``d`` entry
    is real (i.e. not a torus wrap-around artifact of ``jnp.roll``).

    E.g. for ``DIR_N`` the receiver at row ``r`` reads the sender at row
    ``r + 1``, so the mask is ``r < H - 1``.
    """
    H, W = cfg.height, cfg.width
    r = jnp.arange(H)[:, None]
    c = jnp.arange(W)[None, :]
    if d == DIR_N:
        m = r < H - 1
    elif d == DIR_S:
        m = r > 0
    elif d == DIR_W:
        m = c < W - 1
    else:
        m = c > 0
    return jnp.broadcast_to(m, (H, W))


def hop_stage(cfg: EngineConfig, st: MachineState, rows, cols):
    """One routing cycle with per-link virtual-lane arbitration.

    For every cell and direction the link carries **one** message per
    cycle (the physical flit slot).  The round-robin arbiter picks which
    lane gets it (DESIGN §7):

    1. every lane's head message is checked for *admissibility* at the
       receiver — action-queue room under the §4.2 reserve rules if it
       has arrived, else ``lane_capacity`` room in the same lane of the
       receiver's next YX channel (a message never changes lanes);
    2. among the admissible lanes, the one closest after the link's
       rotating pointer ``ch_rr`` wins the slot; the pointer then
       advances past the winner, so a lane with an admissible head is
       served within ``cfg.lanes`` grants of the link (the fairness
       bound pinned by ``tests/test_lanes.py``);
    3. lanes whose head is blocked are simply *skipped* — a full lane
       exerts backpressure on its own traffic only, never on sibling
       lanes.  With ``lanes == 1`` this degenerates to the pre-lane
       wormhole stall (the head stays put).

    Links are arbitrated in fixed direction order N,S,W,E so multiple
    arrivals at one cell in the same cycle are sequenced
    deterministically.  Returns ``(state, hops_this_cycle)``.

    Fault injection (``cfg.faults``, DESIGN §9) lives entirely inside
    this stage: blackout windows mask a link's admissibility (pure
    delay), and the drop/duplicate/corrupt hazards act on the *granted*
    flit — a dropped flit is popped by the sender but never delivered
    (it still counts as a link departure in ``hops``, which is what
    makes the §8 conservation invariant ``sum(TM_HOP) == stat_hops`` a
    real loss detector: deliveries fall short of departures by exactly
    the drop count), a duplicated flit is delivered but *not* popped
    (the sender retransmits it later), and a corrupted flit has one bit
    of its value word flipped for the seal check to catch at pop.
    """
    Q, L, LC = cfg.queue_cap, cfg.lanes, cfg.lane_capacity
    hops = jnp.int32(0)
    aq, aq_n, aq_head = st.aq, st.aq_n, st.aq_head
    ch, ch_n, ch_head = st.ch, st.ch_n, st.ch_head
    ch_rr = st.ch_rr
    tm_cell, tm_lane = st.tm_cell, st.tm_lane
    flt = st.flt
    if cfg.faults is not None:
        from repro.resilience.faults import (FLT_BLACKOUT, FLT_CORRUPT,
                                             FLT_DROP, FLT_DUP, fault_hash16,
                                             is_droppable)
        plan = cfg.faults
        # link id = cell * 4 + dir: one hash stream per physical link
        linkid0 = (rows * cfg.width + cols) * N_DIRS
    liota = rings._iota(L)

    for d in (DIR_N, DIR_S, DIR_W, DIR_E):
        # per-lane head message of every cell's outgoing channel d
        heads = rings.ring_peek(ch[:, :, d], ch_head[:, :, d])  # [H,W,L,MSG]
        occ = ch_n[:, :, d] > 0                                 # [H,W,L]
        # align with receiver
        msg_r = shift_to_receiver(heads, d)
        occ_r = (shift_to_receiver(occ, d)
                 & valid_receiver_mask(cfg, d)[..., None])
        dst_cell = msg_r[..., 1] // cfg.slots                   # [H,W,L]
        tb = yx_target_buffer(cfg, dst_cell,
                              rows[..., None], cols[..., None])
        # AQ admission rule: external pushes respect the local-emission
        # reserve; system actions (allocate / set-future / link-rhizome /
        # link-ack) additionally get the sys_reserve headroom so the
        # future protocol always advances (DESIGN §4.2).
        room = jnp.where(is_protocol(msg_r[..., 0]),
                         rings.ring_free(aq_n, Q, cfg.aq_reserve)[..., None],
                         rings.ring_free(aq_n, Q, cfg.aq_reserve
                                         + cfg.sys_reserve)[..., None])
        adm = (tb == TB_AQ_SELF) & room
        for dd in range(N_DIRS):
            adm = adm | ((tb == dd)
                         & rings.ring_free(ch_n[:, :, dd], LC))
        adm_s = shift_to_sender(occ_r & adm, d)                 # [H,W,L]
        if cfg.faults is not None:
            # blackout windows: the named (cell, dir) link grants
            # nothing while the machine cycle is inside the window —
            # lossless delay, so no detection/repair is ever needed
            for (br, bc, bd, b0, bn) in plan.blackouts:
                if bd != d:
                    continue
                win = (st.cycle >= b0) & (st.cycle < b0 + bn)
                cell = jnp.zeros((cfg.height, cfg.width), bool) \
                    .at[br, bc].set(True)
                dead = cell & win
                flt = flt.at[FLT_BLACKOUT].add(jnp.sum(
                    (dead[..., None] & adm_s).astype(jnp.int32)))
                adm_s = adm_s & ~dead[..., None]

        # round-robin grant at the sender link: the admissible lane
        # closest after the rotating pointer wins the flit slot
        rr = ch_rr[:, :, d]                                     # [H,W]
        pri = (liota[None, None, :] - rr[..., None]) % L        # [H,W,L]
        key = jnp.where(adm_s, pri, L)
        kmin = jnp.min(key, axis=-1)
        granted = jnp.any(adm_s, axis=-1)                       # [H,W]
        # pri is a permutation of 0..L-1, so the min is unique when any
        # lane is admissible; clamp to lane 0 when none is (all gated)
        g = jnp.where(granted,
                      jnp.sum(jnp.where(key == kmin[..., None], liota, 0),
                              axis=-1), 0).astype(jnp.int32)    # [H,W]
        oh_g = liota == g[..., None]                            # [H,W,L]
        sel = jnp.sum(jnp.where(oh_g[..., None], heads, 0), axis=2)

        # per-link fault decisions on the granted flit (sender frame);
        # no-op (and never traced) when cfg.faults is None
        dropm_s = dupm_s = None
        if cfg.faults is not None:
            drp = is_droppable(sel[..., 0]) & granted            # [H,W]
            link = linkid0 + d
            if plan.drop_thr:
                h1 = fault_hash16(plan.seed, st.cycle, link, 1)
                dropm_s = drp & (h1 < plan.drop_thr)
            if plan.dup_thr:
                h2_ = fault_hash16(plan.seed, st.cycle, link, 2)
                dupm_s = drp & (h2_ < plan.dup_thr)
            if plan.corrupt_thr:
                h3 = fault_hash16(plan.seed, st.cycle, link, 3)
                corrm = drp & (h3 < plan.corrupt_thr)
                if dropm_s is not None:
                    corrm = corrm & ~dropm_s
                # flip one value-word bit in transit; the msg_seal check
                # at pop converts this into a detected discard
                bit = jnp.left_shift(jnp.int32(1), 8 + (h3 & 7))
                sel = sel.at[..., 2].set(
                    jnp.where(corrm, sel[..., 2] ^ bit, sel[..., 2]))

        # deliver the granted head at the receiver (re-derives tb/room;
        # granted implies admissible, so acceptance == grant)
        msg_g = shift_to_receiver(sel, d)
        want_r = shift_to_receiver(granted, d) & valid_receiver_mask(cfg, d)
        lane_g = shift_to_receiver(g, d)
        dropm = (want_r & shift_to_receiver(dropm_s, d)
                 if dropm_s is not None else None)
        tb_g = yx_target_buffer(cfg, msg_g[..., 1] // cfg.slots, rows, cols)
        room_g = jnp.where(is_protocol(msg_g[..., 0]),
                           rings.ring_free(aq_n, Q, cfg.aq_reserve),
                           rings.ring_free(aq_n, Q, cfg.aq_reserve
                                           + cfg.sys_reserve))
        aq, aq_n, ch, ch_n, accepted_r = deliver(
            cfg, aq, aq_n, aq_head, ch, ch_n, ch_head,
            msg_g, tb_g, lane_g,
            want_r if dropm is None else want_r & ~dropm, room_g)
        # departed = the flit left the sender's lane this cycle: delivered
        # OR dropped on the link.  hops/stat_hops count departures, so
        # with faults on, departures - deliveries == dropped (the §8/§9
        # conservation detector); without faults the two are identical.
        departed_r = accepted_r if dropm is None else accepted_r | dropm
        popped_r = departed_r
        if dupm_s is not None:
            dupm = accepted_r & shift_to_receiver(dupm_s, d)
            popped_r = departed_r & ~dupm   # sender keeps a dup'd flit
        if cfg.faults is not None:
            if dropm is not None:
                flt = flt.at[FLT_DROP].add(
                    jnp.sum(dropm.astype(jnp.int32)))
            if dupm_s is not None:
                flt = flt.at[FLT_DUP].add(jnp.sum(dupm.astype(jnp.int32)))
        hops = hops + jnp.sum(departed_r.astype(jnp.int32))
        # pop the granted lane at the sender; advance the arbiter pointer
        # past the winner (round-robin fairness)
        acc_s = shift_to_sender(popped_r, d)
        adv_s = shift_to_sender(departed_r, d)
        n2, h2 = rings.ring_pop(ch_n[:, :, d], ch_head[:, :, d], LC,
                                acc_s[..., None] & oh_g)
        ch_n = ch_n.at[:, :, d].set(n2)
        ch_head = ch_head.at[:, :, d].set(h2)
        ch_rr = ch_rr.at[:, :, d].set(jnp.where(adv_s, (g + 1) % L, rr))
        if cfg.telemetry:
            # per-lane grant/blocked attribution at the sender link and
            # per-cell flit arrivals at the receiver (DESIGN §8)
            won = oh_g & acc_s[..., None]                       # [H,W,L]
            tm_lane = tm_lane.at[:, :, d, :, TM_L_GRANT].add(
                won.astype(jnp.int32))
            tm_lane = tm_lane.at[:, :, d, :, TM_L_BLOCK].add(
                (occ & ~won).astype(jnp.int32))
            tm_cell = tm_cell.at[..., TM_HOP].add(
                accepted_r.astype(jnp.int32))

    return st._replace(aq=aq, aq_n=aq_n, ch=ch, ch_n=ch_n, ch_head=ch_head,
                       ch_rr=ch_rr, tm_cell=tm_cell, tm_lane=tm_lane,
                       flt=flt), hops
