"""Fault-tolerance tests: kill-restart resume is bit-identical, atomic
checkpoints, elastic remesh planning, straggler watchdog, gradient
compression with error feedback.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import PRESETS, train
from repro.optim.compression import (compress_with_feedback, decompress,
                                     init_residuals)
from repro.train.checkpoint import Checkpointer
from repro.train.elastic import (HeartbeatMonitor, StepWatchdog,
                                 plan_remesh)

CFG = PRESETS["lm_tiny"]


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = dict(a=jnp.arange(10, dtype=jnp.float32),
                b=[jnp.ones((3, 4)), jnp.zeros((2,), jnp.int32)])
    ck.save(7, tree, extra=dict(note="x"))
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, extra, step = ck.restore(like)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = dict(w=jnp.ones((8, 8)))
    ck.save(1, tree)
    # corrupt the shard
    shard = next((tmp_path / "step_1").glob("shard_*.npz"))
    data = dict(np.load(shard))
    data["leaf_0"] = data["leaf_0"] + 1
    np.savez(shard, **data)
    with pytest.raises(IOError):
        ck.restore(tree)


def test_kill_restart_resume_bit_identical(tmp_path):
    """Train 6 steps straight vs. 3 steps + crash + resume: identical."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    p_straight, _ = train(CFG, steps=6, batch=2, seq=32, ckpt_dir=d1,
                          ckpt_every=100)
    # interrupted run: stop after 3 (checkpoint every 3)
    train(CFG, steps=3, batch=2, seq=32, ckpt_dir=d2, ckpt_every=3)
    # "crash" here; a new process resumes from step 3
    p_resumed, _ = train(CFG, steps=6, batch=2, seq=32, ckpt_dir=d2,
                         ckpt_every=3)
    for a, b in zip(jax.tree.leaves(p_straight),
                    jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = dict(w=jnp.full((64, 64), 3.0))
    ck.save_async(2, tree)
    ck.wait()
    restored, _, _ = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, dict(w=jnp.ones(4) * s))
    assert sorted(ck.all_steps()) == [3, 4]


def test_plan_remesh():
    # full two pods
    p = plan_remesh(512, model_parallel=16, pod_size=256)
    assert p.devices == 512 and p.model == 16
    # lose 5 chips -> lose their TP groups
    p = plan_remesh(507, model_parallel=16, pod_size=256)
    assert p.model == 16 and p.devices <= 507
    assert p.data * p.model * p.pods >= 16
    with pytest.raises(RuntimeError):
        plan_remesh(7, model_parallel=16)


def test_watchdog_fires_on_straggler():
    fired = []
    wd = StepWatchdog(0.05, on_straggler=fired.append)
    wd.arm(step=9)
    time.sleep(0.15)
    assert fired == [9]
    # and does not fire when disarmed in time
    wd.arm(step=10)
    wd.disarm()
    time.sleep(0.1)
    assert fired == [9]


def test_heartbeat_survivors():
    hb = HeartbeatMonitor(4, timeout_s=0.1)
    time.sleep(0.12)
    hb.beat(1)
    hb.beat(3)
    assert hb.survivors() == [1, 3]


def test_compression_error_feedback():
    """Feedback keeps the long-run compressed sum unbiased."""
    rng = np.random.default_rng(0)
    grads_like = dict(w=jnp.zeros((257,)))  # odd size exercises padding
    res = init_residuals(grads_like)
    total_true = np.zeros(257)
    total_comp = np.zeros(257)
    for s in range(30):
        g = dict(w=jnp.asarray(
            rng.standard_normal(257).astype(np.float32)))
        comp, res = compress_with_feedback(g, res)
        deq = decompress(comp, g)
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(deq["w"])
    # per-step error is bounded by the int8 quant step; the accumulated
    # sums track each other thanks to error feedback
    resid = np.abs(np.asarray(res["w"]))
    scale = np.abs(total_true).max()
    assert np.abs(total_true - (total_comp + np.asarray(res["w"]))).max() \
        < 1e-3 * max(scale, 1.0)
    assert resid.max() < 0.1  # residual stays bounded (no divergence)
