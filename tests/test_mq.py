"""Multi-tenant query serving (repro.mq, DESIGN §10).

Pins the four contracts of the Q-batched engine:

* **Q=1 is the old engine**: an MQSession at qbatch=1 replays the
  recorded pre-lanes fingerprint bit-exactly on both backends — the
  widened message format and per-slot counters specialize away;
* **Q-batched is Q engines**: a mixed Q=8 batch (bfs / sssp / cc /
  widest / reliable) over one weighted symmetric stream matches the 8
  single-query runs bit-exactly per slot, and the min-trio slots match
  the NetworkX oracles — over-propagated neutral payloads no-op under
  monotone relaxation;
* **mid-stream admission / retirement**: a tenant admitted at an
  increment boundary re-seeds only its own slot against the live graph
  and converges to the full-graph oracle; a retired slot recycles into
  a different app (composite rebuild) and stays exact;
* **backend parity at Q>1**: jnp and the Pallas megakernel agree on
  cycle counts and every state leaf for a Q=3 mixed batch.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.alloc import rhizome_rcs
from repro.core.apps import APPS
from repro.core.config import EngineConfig
from repro.core.engine import StreamingEngine
from repro.core.reference import bfs_levels, cc_labels, sssp_dists
from repro.graph.streams import StreamSpec, make_stream
from repro.mq.session import DEFAULT_SEEDS, MQSession, QuerySlot

REF = json.loads((pathlib.Path(__file__).parent
                  / "data" / "pre_lanes_reference.json").read_text())


def _mq_cfg(**kw):
    base = dict(height=8, width=8, n_vertices=128, edge_cap=8,
                ghost_slots=64, queue_cap=64, chan_cap=32, futq_cap=8,
                io_stream_cap=2048, lanes=4, chunk=128)
    base.update(kw)
    return EngineConfig(**base)


def _weighted_stream(n=128, n_edges=360, increments=2, seed=11):
    """Symmetric SBM increments with hashed per-pair weights in
    (0.1, 1.0] so sssp / widest / reliable diverge from bfs."""
    incs = make_stream(StreamSpec(n_vertices=n, n_edges=n_edges,
                                  increments=increments, symmetric=True,
                                  seed=seed))
    out = []
    for e in incs:
        e = e.copy()
        lo = np.minimum(e[:, 0], e[:, 1]).astype(np.int64)
        hi = np.maximum(e[:, 0], e[:, 1]).astype(np.int64)
        key = (lo << 21) ^ hi
        w = 0.1 + 0.9 * ((key * 2654435761 % 1000003) / 1000003.0)
        e[:, 2] = w.astype(np.float32).view(np.int32)
        out.append(e)
    return out


def _edge_floats(edges):
    return edges[:, 2].astype(np.int32).view(np.float32)


def _widest_oracle(n, edges, source):
    """Maximin bottleneck capacity by Bellman-Ford iteration."""
    cap = np.zeros(n, np.float64)
    cap[source] = 1e9
    w = _edge_floats(edges).astype(np.float64)
    s, d = edges[:, 0], edges[:, 1]
    while True:
        new = cap.copy()
        np.maximum.at(new, d, np.minimum(cap[s], w))
        if np.array_equal(new, cap):
            return cap.astype(np.float32)
        cap = new


def _seed_single(eng, app_name, source):
    if app_name == "cc":
        cfg = eng.cfg
        vids = np.arange(cfg.n_vertices, dtype=np.int64)[None, :]
        ks = np.arange(cfg.rhizome_cap, dtype=np.int64)[:, None]
        r, c, s = rhizome_rcs(cfg, vids, ks)
        labels = np.broadcast_to(vids.astype(np.float32), r.shape)
        eng.state = eng.state._replace(
            vals=eng.state.vals.at[r, c, s, 0].set(labels))
    else:
        eng.seed(source, DEFAULT_SEEDS[app_name])


# ------------------ Q=1 replays the recorded fingerprint -----------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_q1_bit_exact_vs_recorded_engine(backend):
    """The mq layer at qbatch=1 IS the pre-mq engine: per-increment
    counters and final values replay the pre-lanes recording exactly."""
    incs = make_stream(StreamSpec(**REF["spec"]))
    ses = MQSession(EngineConfig(backend=backend, **REF["cfg"]), qbatch=1)
    ses.eng.seed(0, 0.0)
    ses.slots[0] = QuerySlot(app=APPS["bfs"], source=0, state="active")
    rows = []
    for e in incs:
        r = ses.run_increment(e, max_cycles=500_000)
        rows.append(dict(cycles=r.cycles, hops=r.hops, execs=r.execs,
                         stalls=r.stalls, allocs=r.allocs))
    want = REF["backends"][backend]
    assert rows == want["increments"]
    np.testing.assert_array_equal(
        ses.values(0, 128), np.array(want["values"]))
    # qbatch=1 lifecycle: settles at the first quiet boundary
    assert ses.slots[0].state == "active"
    ses.run_increment(np.zeros((0, 3), np.int32))
    assert ses.settled_slots() == [0]


# ---------------- Q=8 mixed batch == 8 single-query runs -----------------

MIX8 = (("bfs", 0), ("bfs", 7), ("sssp", 3), ("sssp", 11), ("cc", 0),
        ("widest", 5), ("reliable", 9), ("bfs", 23))


def test_q8_mixed_batch_matches_single_runs():
    cfg = _mq_cfg()
    incs = _weighted_stream()
    edges = np.concatenate(incs)
    Q = len(MIX8)
    ses = MQSession(cfg, qbatch=Q, apps=[a for a, _ in MIX8])
    for q, (app, src) in enumerate(MIX8):
        ses.admit(app, src, slot=q)
    for e in incs:
        ses.run_increment(e)
    ses.run_increment(np.zeros((0, 3), np.int32))   # settle boundary
    assert ses.settled_slots() == list(range(Q))

    n = cfg.n_vertices
    for q, (app, src) in enumerate(MIX8):
        eng = StreamingEngine(cfg, app)
        _seed_single(eng, app, src)
        for e in incs:
            eng.run_increment(e)
        np.testing.assert_array_equal(
            ses.values(q), eng.values(),
            err_msg=f"slot {q} ({app}@{src}) != single-query run")

    # and the min-trio slots against the NetworkX oracles
    np.testing.assert_array_equal(ses.values(0), bfs_levels(n, edges, 0))
    np.testing.assert_allclose(
        ses.values(2), sssp_dists(n, edges, _edge_floats(edges), 3),
        rtol=1e-5)
    np.testing.assert_array_equal(ses.values(4), cc_labels(n, edges))
    np.testing.assert_allclose(
        ses.values(5), _widest_oracle(n, edges, 5), rtol=1e-6)

    # per-tenant latency accounting: every settled tenant has a receipt
    for q in range(Q):
        r = ses.retire(q)
        assert r["latency_cycles"] is not None and r["latency_cycles"] > 0
    assert ses.free_slots() == list(range(Q))


# ------------------- mid-stream admission / recycling --------------------

def test_mid_stream_admit_and_recycle():
    cfg = _mq_cfg()
    incs = _weighted_stream(n_edges=240, increments=3, seed=5)
    ses = MQSession(cfg, qbatch=2, apps=["bfs", "sssp"])
    ses.admit("bfs", 0, slot=0)
    ses.run_increment(incs[0])
    # tenant 1 arrives mid-stream: re-seed only slot 1 on the live graph
    ses.admit("sssp", 3, slot=1)
    ses.run_increment(incs[1])
    ses.run_increment(incs[2])
    ses.run_increment(np.zeros((0, 3), np.int32))
    edges = np.concatenate(incs)
    n = cfg.n_vertices
    np.testing.assert_array_equal(ses.values(0), bfs_levels(n, edges, 0))
    np.testing.assert_allclose(
        ses.values(1), sssp_dists(n, edges, _edge_floats(edges), 3),
        rtol=1e-5)
    assert set(ses.settled_slots()) == {0, 1}

    # retire the sssp tenant and recycle its slot into a DIFFERENT app —
    # the composite rebuilds (jit recompile), the bfs tenant rides along
    receipt = ses.retire(1)
    assert receipt["app"] == "sssp" and receipt["latency_cycles"] > 0
    assert ses.free_slots() == [1]
    ses.admit("widest", 5, slot=1)
    assert ses.slots[1].generation == 2
    ses.run_increment(np.zeros((0, 3), np.int32))
    np.testing.assert_allclose(
        ses.values(1), _widest_oracle(n, edges, 5), rtol=1e-6)
    np.testing.assert_array_equal(ses.values(0), bfs_levels(n, edges, 0))

    # label-flood apps cannot join once edges have streamed
    ses.retire(1)
    with pytest.raises(ValueError, match="label-flood"):
        ses.admit("cc", 0, slot=1)


# ---------------------- backend parity at Q > 1 --------------------------

def test_megakernel_parity_q3():
    cfg_kw = dict(height=4, width=4, n_vertices=64, edge_cap=8,
                  ghost_slots=32, queue_cap=64, chan_cap=32, futq_cap=8,
                  io_stream_cap=1024, lanes=4, chunk=64)
    incs = _weighted_stream(n=64, n_edges=120, increments=2, seed=9)
    mix = (("bfs", 0), ("sssp", 3), ("widest", 5))
    finals = {}
    for backend in ("jnp", "pallas"):
        ses = MQSession(_mq_cfg(backend=backend, **cfg_kw), qbatch=3,
                        apps=[a for a, _ in mix])
        for q, (app, src) in enumerate(mix):
            ses.admit(app, src, slot=q)
        cycles = 0
        for e in incs:
            cycles += ses.run_increment(e).cycles
        finals[backend] = (ses.eng.state, cycles,
                          [np.asarray(ses.values(q)) for q in range(3)])
    assert finals["jnp"][1] == finals["pallas"][1]
    for q in range(3):
        np.testing.assert_array_equal(finals["jnp"][2][q],
                                      finals["pallas"][2][q])
    for name, a, b in zip(finals["jnp"][0]._fields, finals["jnp"][0],
                          finals["pallas"][0]):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state leaf '{name}' diverged between backends")
