"""Engine configuration for the AM-CCA-style message-driven machine.

The paper simulates a 32x32 chip of Compute Cells (CCs), each with local
memory (vertex slots), an action queue, and four mesh links (N/S/E/W) with
one-hop-per-cycle YX dimension-ordered routing.  All capacities below are
static so the whole machine state is a fixed-shape JAX pytree.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    # --- chip geometry (paper: 32x32) ---
    height: int = 32
    width: int = 32

    # --- RPVO storage ---
    n_vertices: int = 1024        # logical vertices (roots, round-robin placed)
    edge_cap: int = 8             # edges per RPVO node before spilling to ghost
    ghost_slots: int = 64         # ghost slots per cell (beyond root slots)
    rhizome_cap: int = 1          # co-equal roots per vertex (DESIGN §4.5);
                                  # 1 = classic single root + serial ghost chain

    # --- queues / buffers ---
    queue_cap: int = 32           # per-cell action queue
    chan_cap: int = 8             # per-cell per-direction outgoing channel
    futq_cap: int = 8             # per-future deferred-task queue (Fig. 4)

    # --- virtual lanes (DESIGN §7) ---
    lanes: int = 1                # virtual lanes per physical channel; lane 0
                                  # is the escape lane reserved for protocol /
                                  # continuation traffic, lanes 1.. hash app
                                  # messages by destination.  1 = the classic
                                  # single-FIFO channel (bit-exact with the
                                  # pre-lane engine).
    lane_cap: int = 0             # per-lane ring capacity; 0 -> split the
                                  # physical channel: max(1, chan_cap // lanes)
    park_cap: int = 0             # per-cell park buffer (stalled remote
                                  # emissions store here instead of wedging
                                  # the execute pipeline; drained by
                                  # routing.park_stage); 0 -> chan_cap

    # --- IO channels (paper: IO cells stream edges, 1 edge/cycle each) ---
    n_io_cells: int = 0           # 0 -> one per column (paper-style)
    io_stream_cap: int = 4096     # per-IO-cell residual stream capacity

    # --- allocation policy (paper Fig. 5) ---
    allocator: str = "vicinity"   # "vicinity" (<=2 hops) | "random"
    vicinity_hops: int = 2

    # --- app ---
    n_vals: int = 1               # per-slot application values (BFS: level)
    qbatch: int = 1               # query-batch width (repro.mq, DESIGN §10):
                                  # the vertex value slot carries one value
                                  # per concurrent query and app-like
                                  # messages widen to vector payloads so one
                                  # diffusion wave serves all tenants.  1 =
                                  # the classic single-query engine,
                                  # bit-exact with the pre-mq machine.

    # --- engine ---
    max_cycles: int = 1_000_000
    chunk: int = 256              # cycles per jitted scan chunk / per
                                  # Pallas megakernel launch (K)
    backend: str = "jnp"          # "jnp" (lax chunk runners) | "pallas"
                                  # (fused cycle megakernel, DESIGN §6)

    # --- observability (repro.obs, DESIGN §8) ---
    telemetry: bool = False       # accumulate the per-cell/per-lane
                                  # telemetry planes inside the cycle
                                  # stages and snapshot them per chunk
                                  # into the on-device frame ring; off ->
                                  # 1x1 dummy planes, bit-exact with the
                                  # pre-telemetry engine
    frame_ring: int = 64          # frames (one per chunk) retained on
                                  # device per increment pass; older
                                  # frames are overwritten ring-style

    # --- resilience (repro.resilience, DESIGN §9) ---
    faults: object = None         # FaultPlan | None: seeded deterministic
                                  # fault injection (drop / blackout /
                                  # duplicate / corrupt) applied inside
                                  # cycle_body, plus message seals and the
                                  # end-of-increment repair pass; None ->
                                  # no fault code is traced at all,
                                  # bit-exact with the pre-fault engine
    ingest_guard: bool = False    # throttle load_stream admission from
                                  # the tm_hiw action-queue hi-water mark
                                  # (requires telemetry) so ingest backs
                                  # off under pressure instead of
                                  # manufacturing a livelock

    @property
    def n_cells(self) -> int:
        return self.height * self.width

    @property
    def root_slots(self) -> int:
        return int(math.ceil(self.n_vertices / self.n_cells))

    @property
    def primary_slots(self) -> int:
        # statically reserved rhizome-root region: slot k*root_slots + j is
        # rhizome root k of the vertex with local index j (DESIGN §4.5)
        return self.rhizome_cap * self.root_slots

    @property
    def slots(self) -> int:
        return self.primary_slots + self.ghost_slots

    @property
    def rhizome_stride(self) -> int:
        # cell offset between consecutive rhizome roots of one vertex; odd so
        # it is coprime with the (typically power-of-two) cell count and the
        # roots scatter over the mesh instead of clustering in one row
        return max(1, self.n_cells // self.rhizome_cap) | 1

    @property
    def io_cells(self) -> int:
        return self.n_io_cells if self.n_io_cells > 0 else self.width

    @property
    def lane_capacity(self) -> int:
        # per-lane ring depth: an explicit lane_cap wins, otherwise the
        # physical channel's capacity is split evenly over the lanes (the
        # classic virtual-channel organization: same buffer budget, more
        # independently-queued FIFOs)
        return self.lane_cap if self.lane_cap > 0 else \
            max(1, self.chan_cap // self.lanes)

    @property
    def park_capacity(self) -> int:
        # lanes == 1 keeps a 1-deep dummy ring (never pushed) so the
        # state stays fixed-shape without spending memory on it
        if self.lanes == 1:
            return 1
        return self.park_cap if self.park_cap > 0 else self.chan_cap

    @property
    def msg_words(self) -> int:
        # message record width in int32 words (DESIGN §10): the classic
        # 5-word record, plus one extension word per query slot beyond the
        # first.  Payload slot 0 stays in word 2 and the seal stays in
        # word 4, so the qbatch == 1 layout is byte-identical to the
        # pre-mq flit (see core/msg.py).
        from repro.core.msg import MSG_WORDS
        return MSG_WORDS + max(0, self.qbatch - 1)

    @property
    def aq_reserve(self) -> int:
        # Reserved action-queue slots so the active action's *local*
        # emissions always complete -> no self-deadlock (see DESIGN 4.2).
        # With rhizomes an app action additionally broadcasts to up to
        # rhizome_cap-1 sibling roots, any of which may be local.
        return self.edge_cap + 2 + (self.rhizome_cap - 1)

    @property
    def sys_reserve(self) -> int:
        # System actions (allocate / set-future) may fill the queue this
        # much further than application messages: combined with head
        # rotation this guarantees the future-LCO protocol always makes
        # progress under congestion (no FIFO head-of-line deadlock).
        return 2

    def validate(self) -> None:
        assert self.height >= 2 and self.width >= 2
        assert self.backend in ("jnp", "pallas"), \
            f"unknown engine backend {self.backend!r}"
        assert self.queue_cap > self.aq_reserve + self.sys_reserve + 1, \
            "queue too small for reserves (DESIGN §4.2); with rhizome_cap=" \
            f"{self.rhizome_cap} need queue_cap > " \
            f"{self.aq_reserve + self.sys_reserve + 1}"
        assert self.n_cells * self.slots < 2**31, "address overflows int32"
        assert self.edge_cap >= 1 and self.futq_cap >= 2
        assert self.lanes >= 1 and self.lane_cap >= 0 and self.park_cap >= 0
        assert self.frame_ring >= 2, \
            "frame_ring must hold >= 2 frames (the flight recorder diffs " \
            "consecutive frames, DESIGN §8)"
        assert self.lane_capacity >= 1, "lane_capacity must be >= 1"
        assert self.park_capacity >= 1, "park_capacity must be >= 1"
        assert 1 <= self.rhizome_cap <= self.n_cells, \
            "rhizome_cap must be in [1, n_cells]"
        # rhizome roots of one vertex must land on distinct cells: the k-th
        # root lives at (v + k*stride) % n_cells (DESIGN §4.5)
        cells = {(k * self.rhizome_stride) % self.n_cells
                 for k in range(self.rhizome_cap)}
        assert len(cells) == self.rhizome_cap, \
            "rhizome_stride collides rhizome roots on one cell; pick a " \
            "rhizome_cap with distinct k*stride mod n_cells"
        assert self.qbatch >= 1, "qbatch must be >= 1"
        assert self.qbatch <= 32, \
            "qbatch > 32 overflows the int32 qsel bitmask (msg word 3, " \
            "DESIGN §10); shard tenants over several sessions instead"
        if self.qbatch > 1:
            assert self.faults is None, \
                "faults + qbatch > 1 is unsupported: the OP_REPAIR io " \
                "sentinel rows carry a single value word (DESIGN §9/§10); " \
                "run fault injection on a qbatch=1 engine"
            assert self.n_vals == self.qbatch, \
                "qbatch > 1 requires n_vals == qbatch (the query axis IS " \
                "the value axis; StreamingEngine sets both from the app)"
        if self.faults is not None:
            self.faults.validate(self)
        if self.ingest_guard:
            assert self.telemetry, \
                "ingest_guard needs the tm_hiw telemetry plane " \
                "(set telemetry=True, DESIGN §9)"
        if self.rhizome_cap > 1:
            # a rhizome activation drains up to futq_cap deferred inserts
            # back onto the LOCAL action queue in one action; the drain
            # must fit the local-emission reserve (DESIGN §4.2/§4.5)
            assert self.futq_cap <= self.aq_reserve, \
                f"futq_cap={self.futq_cap} exceeds the local-emission " \
                f"reserve {self.aq_reserve}; shrink futq_cap or raise " \
                "edge_cap/rhizome_cap"
