"""Vectorized fixed-capacity ring buffers over the cell grid.

Every queue in the machine (action queues, channel buffers, future queues)
is a ring buffer with leading batch dims (e.g. ``[H, W]`` or ``[H, W, S]``),
a capacity axis, and a trailing message-word axis.

Implementation note (§Perf, cca cell): pushes/pops are **one-hot
`where` ops, not scatters/gathers**.  GSPMD partitions elementwise ops
over the sharded cell grid trivially, whereas scatters with index arrays
were being partitioned with per-cycle all-gathers of the updates (found
in the chip_512x512 HLO audit).  On CPU the one-hot form is also faster:
XLA vectorizes the compare+select, while scatter serializes.
"""
from __future__ import annotations

import jax.numpy as jnp


def _iota(cap, dtype=jnp.int32):
    return jnp.arange(cap, dtype=dtype)


def ring_push(buf, cnt, head, msg, mask):
    """Masked FIFO push: append ``msg`` at the tail wherever ``mask``.

    Shapes: ``buf [*B, CAP, W]``, ``cnt/head/mask [*B]``, ``msg [*B, W]``
    — any number of leading batch dims ``*B`` (per-cell ``[H, W]``,
    per-slot ``[H, W, S]``, per-lane ``[H, W, L]``, or the IO row ``[W]``
    / ``[W, L]`` slices).  Returns the updated ``(buf, cnt)``; ``head``
    is unchanged (pushes write the tail).

    The push is **unconditional where masked**: the caller must
    guarantee ``cnt < CAP`` wherever ``mask`` is True — admission
    predicates (:func:`ring_free`, the reserve rules of
    ``routing.deliver``) belong to the caller, not the ring.
    """
    cap = buf.shape[-2]
    tail = (head + cnt) % cap
    oh = (_iota(cap) == tail[..., None]) & mask[..., None]     # [*B, CAP]
    buf = jnp.where(oh[..., None], msg[..., None, :], buf)
    cnt = cnt + mask.astype(cnt.dtype)
    return buf, cnt


def ring_peek(buf, head):
    """Read every ring's head element without consuming it.

    Shapes: ``buf [*B, CAP, W]``, ``head [*B]``; returns ``[*B, W]``
    (zeros where a ring is empty — callers gate on their own occupancy
    mask, e.g. ``cnt > 0``).
    """
    cap = buf.shape[-2]
    oh = _iota(cap) == (head % cap)[..., None]                 # [*B, CAP]
    return jnp.sum(jnp.where(oh[..., None], buf, 0), axis=-2)


def ring_pop(cnt, head, cap, mask):
    """Masked pop: advance ``head`` and decrement ``cnt`` where ``mask``.

    The element itself is read beforehand via :func:`ring_peek` (the
    buffer is not cleared — a slot's words are dead once the head passes
    them).  Returns the updated ``(cnt, head)``; the caller must only
    pop non-empty rings.
    """
    m = mask.astype(cnt.dtype)
    return cnt - m, (head + m) % cap


def ring_free(cnt, cap, reserve=0):
    """Admission predicate: True where a push would leave at least
    ``reserve`` slots still free (``cnt < cap - reserve``).

    ``reserve`` implements the DESIGN §4.2 action-queue rules: external
    pushes reserve the active action's local-emission region
    (``aq_reserve``) and application pushes additionally the system
    headroom (``sys_reserve``); channel-lane admission uses
    ``reserve=0`` against ``cfg.lane_capacity``.
    """
    return cnt < (cap - reserve)
