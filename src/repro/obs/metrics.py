"""Small latency/throughput summaries (DESIGN §8).

Host-side helpers shared by the serving surface (``launch/serve.py``)
and the profile benchmarks: percentile summaries over wall-clock
samples, and engine-rate summaries over a telemetry frame log.  Pure
numpy — no engine imports.
"""
from __future__ import annotations

import numpy as np

from repro.obs.frames import (FS_BACKLOG, FS_CYCLE, FS_EXEC, FS_HOPS,
                              FS_INFLIGHT, FS_STALL, FrameLog)


def summarize(samples, unit: str = "s") -> dict:
    """Percentile summary of a list of wall-clock samples."""
    a = np.asarray(list(samples), np.float64)
    if a.size == 0:
        return dict(n=0, unit=unit)
    return dict(
        n=int(a.size), unit=unit, mean=float(a.mean()),
        p50=float(np.percentile(a, 50)), p90=float(np.percentile(a, 90)),
        p99=float(np.percentile(a, 99)), max=float(a.max()))


def render_summary(name: str, samples, unit: str = "ms",
                   scale: float = 1e3) -> str:
    """One-line latency summary (``scale`` converts samples to ``unit``)."""
    s = summarize([x * scale for x in samples], unit)
    if not s["n"]:
        return f"[{name}] no samples"
    return (f"[{name}] n={s['n']} mean={s['mean']:.2f}{unit} "
            f"p50={s['p50']:.2f} p90={s['p90']:.2f} p99={s['p99']:.2f} "
            f"max={s['max']:.2f}{unit}")


def engine_rates(frames: FrameLog) -> dict:
    """Chip-wide rates from a frame log: activity per machine cycle plus
    mean queue pressure (the serving/benchmark summary surface)."""
    s = frames.scal
    # cycle SPAN of the log (frame 0 is the increment-start baseline;
    # the counters reset there, so span is the right normalizer)
    cycles = max(1, int(s[-1, FS_CYCLE] - s[0, FS_CYCLE]))
    return dict(
        cycles=cycles,
        execs_per_cycle=float(s[-1, FS_EXEC]) / cycles,
        hops_per_cycle=float(s[-1, FS_HOPS]) / cycles,
        stalls_per_cycle=float(s[-1, FS_STALL]) / cycles,
        mean_backlog=float(s[:, FS_BACKLOG].mean()),
        mean_in_flight=float(s[:, FS_INFLIGHT].mean()),
        peak_backlog=int(s[:, FS_BACKLOG].max()),
        peak_in_flight=int(s[:, FS_INFLIGHT].max()))
