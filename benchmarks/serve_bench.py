"""bench_serve — multi-tenant query serving over a live R-MAT stream.

One MQSession carries a mixed BFS / SSSP / CC / widest batch of Q
queries through an evolving R-MAT graph (repro.mq, DESIGN §10), with
FrontDesk admission and per-query time-to-quiescence accounting.  The
baseline is the same stream replayed once per query on a single-query
engine; both sides are measured in MACHINE CYCLES (the architectural
metric every other bench uses), so

    speedup = sum(serial cycles over Q queries) / batched cycles

is the aggregate-throughput multiplier of sharing one diffusion wave —
global quiescence of the batch tracks the *slowest* tenant, not the sum,
so Q-way batches land well above 1x (the serve-smoke CI gate pins >= 2x
for the Q=8 mix).

Per-query correctness is asserted against the single-query runs
(bit-exact — over-propagated neutral payloads no-op under monotone
relaxation), and the result record lands in ``results/bench_serve.json``.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core.alloc import rhizome_rcs
from repro.core.config import EngineConfig
from repro.core.engine import StreamingEngine
from repro.graph.streams import StreamSpec, make_stream
from repro.mq.frontdesk import FrontDesk
from repro.mq.session import DEFAULT_SEEDS, MQSession
from repro.obs import metrics

SCALES = {
    # the serving mesh is sized ABOVE the single-query sweet spot on
    # purpose: one wave keeps an 8x8 grid's execute bandwidth busy by
    # itself (measured speedup there caps at ~1.9x), while a Q-way batch
    # exists to soak up idle cells — 16x16 gives it the headroom the
    # paper's machines have, and the same mix lands >2x
    "ci": dict(height=16, width=16, n_vertices=256, n_edges=700,
               increments=3, chunk=128),
    "mid": dict(height=24, width=24, n_vertices=1024, n_edges=4000,
                increments=5, chunk=256),
    "paper": dict(height=32, width=32, n_vertices=4096, n_edges=20000,
                  increments=10, chunk=256),
}

# the Q=8 mixed tenant batch (app, source); CC is the label-flood tenant
# and must be admitted before the stream starts
QUERY_MIX = (("bfs", 0), ("bfs", 17), ("bfs", 42), ("sssp", 5),
             ("sssp", 23), ("sssp", 77), ("cc", 0), ("widest", 11))


def _serve_cfg(p):
    return EngineConfig(
        height=p["height"], width=p["width"], n_vertices=p["n_vertices"],
        edge_cap=8,
        ghost_slots=max(64, 8 * p["n_edges"]
                        // (p["height"] * p["width"])),
        # a Q-way batch pushes ~Q machines' worth of relaxation waves
        # through one machine's buffers (same total message count as the
        # serial runs, compressed in time), so the serving preset scales
        # every congestion defence the single-query benches run with:
        # deep virtual lanes for the hub-convergent R-MAT traffic
        # (DESIGN §7), multi-root rhizomes so hub inserts shard over
        # co-equal roots (§4.5), 4x queue/channel depth for the
        # Q-amplified wave volume, and the tm_hiw ingest guard (§9) so
        # edge admission backs off instead of parking the fabric solid.
        # Undersized single-query margins (queue_cap=32, chan_cap=16,
        # lanes<=4) wedge on this stream — measured, not theoretical.
        queue_cap=256, chan_cap=64, futq_cap=8, io_stream_cap=8192,
        lanes=8, rhizome_cap=4, telemetry=True, ingest_guard=True,
        chunk=p["chunk"], max_cycles=4_000_000)


def _stream(p):
    """Symmetric R-MAT increments with per-edge random weights in
    (0.1, 1.0] — undirected for the CC tenant, weighted so the SSSP and
    widest tenants diverge from BFS."""
    spec = StreamSpec(n_vertices=p["n_vertices"], n_edges=p["n_edges"],
                      increments=p["increments"], kind="rmat",
                      symmetric=True, seed=7)
    incs = make_stream(spec)
    rng = np.random.default_rng(13)
    out = []
    for e in incs:
        e = e.copy()
        # mirror pairs ride adjacent in the symmetric stream layout, but
        # increments shuffle them apart — hash each undirected pair to
        # one weight so both directions agree
        lo = np.minimum(e[:, 0], e[:, 1]).astype(np.int64)
        hi = np.maximum(e[:, 0], e[:, 1]).astype(np.int64)
        key = (lo << 21) ^ hi
        w = (0.1 + 0.9 * ((key * 2654435761 % 1000003) / 1000003.0))
        e[:, 2] = w.astype(np.float32).view(np.int32)
        out.append(e)
    return out


def _serial_run(cfg, app, source, incs):
    """Single-query baseline: same stream, one tenant, total cycles."""
    eng = StreamingEngine(cfg, app)
    if app == "cc":
        vids = np.arange(cfg.n_vertices, dtype=np.int64)[None, :]
        ks = np.arange(cfg.rhizome_cap, dtype=np.int64)[:, None]
        r, c, s = rhizome_rcs(eng.cfg, vids, ks)
        labels = np.broadcast_to(vids.astype(np.float32), r.shape)
        eng.state = eng.state._replace(
            vals=eng.state.vals.at[r, c, s, 0].set(labels))
    else:
        eng.seed(source, DEFAULT_SEEDS[app])
    cycles = 0
    for e in incs:
        cycles += eng.run_increment(e).cycles
    return eng, cycles


def bench_serve(scale: str = "ci",
                out_json: str = "results/bench_serve.json") -> dict:
    p = SCALES[scale]
    cfg = _serve_cfg(p)
    incs = _stream(p)
    Q = len(QUERY_MIX)

    # ---- batched serving run ----
    ses = MQSession(cfg, qbatch=Q, apps=[a for a, _ in QUERY_MIX])
    fd = FrontDesk(ses)
    for app, src in QUERY_MIX:
        if app == "cc":
            ses.admit(app, src)       # label flood: pre-stream only
        else:
            fd.submit(app, src)
    batch_cycles = 0
    for e in incs:
        batch_cycles += fd.step(e).cycles
    # one empty flush beat so tenants that last changed in the final
    # increment observe a quiet boundary and settle (counted — it is
    # machine time the serving run spent)
    batch_cycles += fd.step(np.zeros((0, 3), np.int32)).cycles
    for q, s in enumerate(ses.slots):
        if s.state != "free":
            fd.receipts.append(ses.retire(q))
    # a retired slot's value plane stays intact until the slot is
    # recycled (nothing was re-admitted) — read per-query results now.
    # Tenants land in slots in ADMISSION order (CC grabs the first free
    # slot, FrontDesk fills the rest in submit order), so map each
    # (app, source) tenant to its slot via the retirement receipts.
    batch_values = {q: ses.values(q) for q in range(Q)}
    slot_of = {(r["app"], r["source"]): r["slot"] for r in fd.receipts}

    # ---- serial baselines + per-query exactness gate ----
    serial_cycles = []
    exact = []
    for q, (app, src) in enumerate(QUERY_MIX):
        eng, cyc = _serial_run(cfg, app, src, incs)
        serial_cycles.append(cyc)
        exact.append(bool(np.array_equal(
            eng.values(), batch_values[slot_of[(app, src)]])))

    lat = [r["latency_cycles"] for r in fd.receipts
           if r["latency_cycles"] is not None]
    summary = metrics.summarize(lat, unit="cycles")
    speedup = float(sum(serial_cycles)) / max(1, batch_cycles)
    rec = dict(
        scale=scale, qbatch=Q,
        queries=[dict(slot=slot_of[(a, s)], app=a, source=s,
                      serial_cycles=serial_cycles[q],
                      exact=exact[q])
                 for q, (a, s) in enumerate(QUERY_MIX)],
        receipts=[{k: v for k, v in r.items() if k != "values"}
                  for r in fd.receipts],
        latency=summary,
        p50_cycles=summary.get("p50"), p99_cycles=summary.get("p99"),
        batch_cycles=int(batch_cycles),
        serial_cycles_total=int(sum(serial_cycles)),
        speedup=round(speedup, 3),
        all_exact=all(exact),
        deferrals=fd.deferrals,
    )
    pathlib.Path(out_json).parent.mkdir(exist_ok=True)
    pathlib.Path(out_json).write_text(json.dumps(rec, indent=2))
    return rec
