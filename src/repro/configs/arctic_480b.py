"""--arch arctic-480b (exact published config; see lm_archs.py)."""
from repro.configs.lm_archs import ARCTIC as CONFIG
from repro.configs.registry import get

BUNDLE = get("arctic-480b")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
