"""Deterministic fault injection for the cycle engine (DESIGN §9).

A :class:`FaultPlan` is a *static*, seeded description of the hazards to
inject inside ``cycle_body`` — it rides on :class:`EngineConfig` (a jit
static argument), so the faulty cycle compiles to a different XLA
program while ``cfg.faults is None`` stays bit-identical to the
pre-fault engine (the same pattern the telemetry planes use, DESIGN §8).
Because the injection happens inside the shared cycle semantics, the
Pallas cycle megakernel inherits it through the generic leaf flattening
with zero kernel changes.

Fault decisions are pure counter hashes of ``(seed, cycle, link,
salt)`` — no PRNG state rides in ``MachineState`` — so both backends
make bit-identical decisions and a restored checkpoint replays the
exact same fault sequence (what makes kill-and-resume testable under
fire).

The four hazards:

* **drop** — a granted application flit vanishes on the link: the sender
  pops, the receiver never sees it.  Only *reloss-safe* traffic
  (``OP_APP`` / ``OP_REPAIR`` monotone relaxes, :func:`is_droppable`) is
  ever dropped: losing an ``OP_INSERT_EDGE`` would lose graph structure
  and losing a protocol/continuation message would wedge the Fig. 3/4
  state machines — neither is recoverable from durable values, so a
  real system must (and ours does) transport them reliably.
* **blackout** — a named ``(row, col, dir)`` link is dead for a cycle
  window: its lanes are never granted.  Pure delay, lossless, applies
  to all traffic.
* **duplicate** — the receiver takes the flit but the sender keeps it
  (a retransmission): the message is delivered again later.  Safe for
  the same opcode set (monotone relaxes are idempotent).
* **corrupt** — one bit of the value word of a granted application flit
  is flipped in transit.  Every message carries an XOR seal over its
  other words (``msg.msg_seal``, set at the two injection chokepoints);
  the execute stage validates the seal at pop and discards corrupted
  messages as counted no-ops, converting corruption into a *detected*
  drop instead of silently poisoning the monotone fixpoint (a
  corrupted-low BFS level could never be un-relaxed).

Injection is accounted in the ``flt`` state leaf (``FLT_*`` indices) —
the end-of-increment loss detector cross-checks it against the §8
conservation invariant (``stat_hops`` counts link *departures*,
``sum(TM_HOP)`` counts *deliveries*; the gap is exactly the drop count)
and triggers the bounded repair pass (``engine._repair_rounds``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.msg import OP_APP

# OP_REPAIR lives in msg.py; imported lazily below to avoid a cycle at
# module import time (msg imports nothing from here).

# ---- fault-counter leaf indices: ``MachineState.flt`` [N_FLT] i32 ----
FLT_DROP = 0       # app flits dropped on a link
FLT_DUP = 1        # app flits delivered twice (sender kept its copy)
FLT_CORRUPT = 2    # corrupted flits caught by the seal check at pop
FLT_BLACKOUT = 3   # occupied link-cycles suppressed by a blackout window
N_FLT = 4

# 16-bit decision space: a rate r fires where hash16 < int(r * 65536)
_HASH_BITS = 16
_HASH_SPACE = 1 << _HASH_BITS

# 32-bit odd mixing constants (Murmur3/xxhash finalizers), written as
# their int32 two's-complement values so jnp.int32 accepts them
_M1 = -1640531535   # 0x9E3779B1  (golden-ratio increment)
_M2 = -2048144789   # 0x85EBCA6B
_M3 = -1028477387   # 0xC2B2AE35
_M4 = 668265263     # 0x27D4EB2F


def _srl(x, n):
    return jax.lax.shift_right_logical(x, jnp.int32(n))


def fault_hash16(seed: int, cycle, link, salt: int):
    """Deterministic per-(cycle, link, salt) hash in ``[0, 65536)``.

    ``seed``/``salt`` are static python ints; ``cycle`` (scalar) and
    ``link`` (any int32 array, e.g. ``cell * N_DIRS + dir``) are traced.
    int32 multiply/add wrap mod 2^32 under XLA, which is exactly the
    mixing we want; the final mask keeps the value non-negative.
    """
    k = jnp.int32((seed * _M4 + salt * 40503) & 0x7FFFFFFF)
    h = (jnp.asarray(cycle, jnp.int32) * jnp.int32(_M1)
         + jnp.asarray(link, jnp.int32) * jnp.int32(_M2) + k)
    h = (h ^ _srl(h, 16)) * jnp.int32(_M2)
    h = (h ^ _srl(h, 13)) * jnp.int32(_M3)
    h = h ^ _srl(h, 16)
    return h & jnp.int32(_HASH_SPACE - 1)


def is_droppable(op):
    """True where ``op`` may legally be dropped/duplicated/corrupted:
    the monotone-relax application traffic, re-derivable from durable
    vertex values (see module docstring).  Broadcasts over ``op``."""
    from repro.core.msg import OP_REPAIR
    return (op == OP_APP) | (op == OP_REPAIR)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Static, seeded fault schedule (rides on ``EngineConfig.faults``).

    Rates are per granted application flit per link per cycle;
    ``blackouts`` is a tuple of ``(row, col, dir, start_cycle,
    n_cycles)`` link outages (``dir`` is a ``msg.DIR_*`` code, cycle
    window measured on the machine's monotone ``cycle`` counter).
    ``max_repair_rounds`` bounds the end-of-increment repair pass.

    Frozen + all-hashable fields: ``EngineConfig`` is a jit static
    argument, so the plan must be too.
    """
    seed: int = 0
    drop_rate: float = 0.0
    dup_rate: float = 0.0
    corrupt_rate: float = 0.0
    blackouts: tuple = ()
    max_repair_rounds: int = 3

    # ---- static 16-bit thresholds (0 compiles the hazard away) ----
    @property
    def drop_thr(self) -> int:
        return int(self.drop_rate * _HASH_SPACE)

    @property
    def dup_thr(self) -> int:
        return int(self.dup_rate * _HASH_SPACE)

    @property
    def corrupt_thr(self) -> int:
        return int(self.corrupt_rate * _HASH_SPACE)

    def safe(self) -> "FaultPlan":
        """The *reliable-transport* twin of this plan: same seed and
        repair budget, zero hazard rates, no blackouts.  The repair pass
        runs under it (recovery traffic uses acknowledged delivery in
        BLADYG-style systems, DESIGN §9) — crucially the state *shapes*
        (the ``flt`` leaf) are unchanged, so the boundary state flows
        into the repair jit without a host round-trip."""
        return dataclasses.replace(self, drop_rate=0.0, dup_rate=0.0,
                                   corrupt_rate=0.0, blackouts=())

    def validate(self, cfg) -> None:
        for r in (self.drop_rate, self.dup_rate, self.corrupt_rate):
            assert 0.0 <= r < 1.0, f"fault rate {r} outside [0, 1)"
        assert self.max_repair_rounds >= 1
        for b in self.blackouts:
            r, c, d, start, n = b
            assert 0 <= r < cfg.height and 0 <= c < cfg.width, \
                f"blackout {b}: cell off-grid"
            assert 0 <= d < 4, f"blackout {b}: bad direction"
            assert n >= 1 and start >= 0, f"blackout {b}: bad window"
