# OPTIONAL layer: custom Pallas kernels for compute hot-spots, one dir
# per kernel with kernel.py (Pallas body) / ops.py (jitted wrapper,
# interpret fallback off-TPU) / ref.py (pure-jnp reference).
#
#   spmm/            one-hot MXU scatter-SpMM (GNN aggregation)
#   flash_attention/ blockwise attention (LM serving/training)
#   embedding_bag/   gathered-sum embedding lookups (DLRM)
#   cca_cycle/       fused CCA cycle megakernel: K engine cycles per
#                    launch, MachineState resident in VMEM (DESIGN §6)
