"""Pallas TPU flash-attention forward (causal, GQA).

Grid: (B * H, nQ, nK) — the K dimension is innermost ("arbitrary"), so the
output block plus the running (m, l) scratch accumulate across K steps in
VMEM (the canonical TPU flash schedule: HBM->VMEM stream of KV tiles
through the MXU).  GQA is handled in the BlockSpec index maps: the KV tile
for flat head h comes from kv head h // G — no materialized repeat.

Causal skipping: K tiles strictly above the diagonal still run (grid is
static) but their contribution is masked; the @pl.when(init) guard keeps
the accumulator exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, bq, bk, causal):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, dh]
    k = k_ref[0].astype(jnp.float32)                  # [bk, dh]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq,bk]
    if causal:
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                   # [bq, bk]
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)                  # [bk, dh]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, bq=128, bk=128,
                        interpret=False):
    """q: [B, Tq, H, dh]; k/v: [B, Tk, Kh, dh] -> [B, Tq, H, dh]."""
    B, Tq, H, dh = q.shape
    Tk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0
    scale = 1.0 / np.sqrt(dh)
    # flatten (B, H) -> rows of a [B*H, T, dh] layout
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kh, Tk, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kh, Tk, dh)

    def kv_map(b, iq, ik):
        return (b // G, ik, 0)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, bq=bq, bk=bk,
                          causal=causal),
        grid=(B * H, Tq // bq, Tk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, dh), kv_map),
            pl.BlockSpec((1, bk, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Tq, dh).transpose(0, 2, 1, 3)
