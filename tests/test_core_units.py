"""Unit tests for the message-driven engine's building blocks."""
import ast
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rings
from repro.core.alloc import choose_alloc_cell, vicinity_offsets
from repro.core.config import EngineConfig
from repro.core.msg import (TB_AQ_SELF, TB_CHAN_E, TB_CHAN_N, TB_CHAN_S,
                            TB_CHAN_W, f2i, i2f, make_msg)
from repro.core.routing import yx_target_buffer


def test_msg_roundtrip():
    for v in (0.0, 1.0, -3.5, 1e9, 2.5e-4):
        assert float(i2f(f2i(v))) == np.float32(v)


def test_make_msg_shape():
    m = make_msg(1, jnp.arange(4), 7)
    assert m.shape == (4, 5)
    assert int(m[2, 1]) == 2 and int(m[0, 2]) == 7


def test_ring_push_pop_fifo():
    buf = jnp.zeros((2, 4, 5), jnp.int32)
    cnt = jnp.zeros((2,), jnp.int32)
    head = jnp.zeros((2,), jnp.int32)
    msgs = [make_msg(1, i, i * 10) for i in range(3)]
    for m in msgs:
        buf, cnt = rings.ring_push(buf, cnt, head,
                                   jnp.broadcast_to(m, (2, 5)),
                                   jnp.array([True, False]))
    assert int(cnt[0]) == 3 and int(cnt[1]) == 0
    outs = []
    for _ in range(3):
        outs.append(np.asarray(rings.ring_peek(buf, head))[0])
        cnt, head = rings.ring_pop(cnt, head, 4, jnp.array([True, False]))
    assert [o[1] for o in outs] == [0, 1, 2]  # FIFO order
    assert int(cnt[0]) == 0


def test_ring_wraparound():
    buf = jnp.zeros((1, 2, 5), jnp.int32)
    cnt = jnp.zeros((1,), jnp.int32)
    head = jnp.zeros((1,), jnp.int32)
    t = jnp.array([True])
    for i in range(5):  # push/pop interleaved past capacity
        buf, cnt = rings.ring_push(buf, cnt, head, make_msg(1, i)[None], t)
        got = int(rings.ring_peek(buf, head)[0, 1])
        assert got == i
        cnt, head = rings.ring_pop(cnt, head, 2, t)


def test_yx_routing_vertical_first():
    cfg = EngineConfig(height=4, width=4, n_vertices=16)
    r = jnp.array(1)
    c = jnp.array(1)
    # dst below and right -> go S first (vertical first)
    assert int(yx_target_buffer(cfg, jnp.array(3 * 4 + 3), r, c)) == TB_CHAN_S
    assert int(yx_target_buffer(cfg, jnp.array(0 * 4 + 3), r, c)) == TB_CHAN_N
    # same row -> horizontal
    assert int(yx_target_buffer(cfg, jnp.array(1 * 4 + 3), r, c)) == TB_CHAN_E
    assert int(yx_target_buffer(cfg, jnp.array(1 * 4 + 0), r, c)) == TB_CHAN_W
    # arrived
    assert int(yx_target_buffer(cfg, jnp.array(1 * 4 + 1), r, c)) == TB_AQ_SELF


def test_vicinity_offsets_bound():
    offs = vicinity_offsets(2)
    assert len(offs) == 24
    assert (np.abs(offs).max(axis=1) <= 2).all()
    assert (np.abs(offs).max(axis=1) >= 1).all()


@pytest.mark.parametrize("module_name", ["repro.core.rings",
                                         "repro.core.routing"])
def test_public_docstrings(module_name):
    """pydocstyle-level gate (the tool isn't pinned in this image): every
    public function of the ring/routing modules documents itself — a
    docstring exists, starts on the first line with a capital letter or
    backtick, and the summary sentence ends with a period.  deliver's
    reserve-predicate contract riding on this is load-bearing: each
    caller supplies a different §4.2 admission rule."""
    import importlib
    mod = importlib.import_module(module_name)
    tree = ast.parse(inspect.getsource(mod))
    funcs = [n for n in tree.body if isinstance(n, ast.FunctionDef)
             and not n.name.startswith("_")]
    assert funcs, f"no public functions found in {module_name}"
    for fn in funcs:
        doc = ast.get_docstring(fn)
        assert doc, f"{module_name}.{fn.name} is missing a docstring"
        first = doc.strip().splitlines()[0].strip()
        assert first and (first[0].isupper() or first[0] in "`\"'["), \
            f"{module_name}.{fn.name}: summary should start capitalized"
        summary = doc.strip().split("\n\n")[0].rstrip()
        assert summary.endswith((".", ":", "::")), \
            f"{module_name}.{fn.name}: summary should end with a period"
    if module_name.endswith("routing"):
        doc = next(ast.get_docstring(f) for f in funcs
                   if f.name == "deliver")
        assert "reserve" in doc.lower() and "aq_room" in doc, \
            "deliver must document the reserve-predicate contract"


@pytest.mark.parametrize("policy", ["vicinity", "random"])
def test_choose_alloc_cell_in_range(policy):
    cfg = EngineConfig(height=8, width=8, n_vertices=64, allocator=policy)
    rows = jnp.tile(jnp.arange(8, dtype=jnp.int32)[:, None], (1, 8))
    cols = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None, :], (8, 1))
    for rot in range(5):
        cells = np.asarray(choose_alloc_cell(cfg, rows, cols,
                                             jnp.full((8, 8), rot, jnp.int32)))
        assert ((cells >= 0) & (cells < 64)).all()
        if policy == "vicinity":
            tr, tc = cells // 8, cells % 8
            cheb = np.maximum(np.abs(tr - np.asarray(rows)),
                              np.abs(tc - np.asarray(cols)))
            assert (cheb <= cfg.vicinity_hops).all()
            # ring excludes self unless clipped at the border
            interior = ((np.asarray(rows) >= 2) & (np.asarray(rows) < 6)
                        & (np.asarray(cols) >= 2) & (np.asarray(cols) < 6))
            assert (cheb[interior] >= 1).all()
