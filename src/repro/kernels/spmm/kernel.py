"""Pallas TPU scatter-SpMM: segment-sum of edge messages via one-hot MXU
matmuls (DESIGN §2 "MXU exploitation").

GPU GNN kernels scatter with atomics; TPU has no atomics but has a 128x128
systolic array.  With edges sorted by destination, a [bn x be] one-hot
ownership matrix turns the scatter into a dense matmul:

    out[r*bn:(r+1)*bn] += onehot(dst_block - r*bn) @ msgs_block

Scalar-prefetched per-edge-block (min, max) destination ranges let the
kernel skip disjoint (row-block, edge-block) pairs — the sparsity
structure — while everything that does run is MXU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _spmm_kernel(ranges_ref, dst_ref, msgs_ref, o_ref, *, bn, be):
    r = pl.program_id(0)
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    lo = ranges_ref[e, 0]
    hi = ranges_ref[e, 1]
    overlap = (hi >= r * bn) & (lo < (r + 1) * bn)

    @pl.when(overlap)
    def _accum():
        dst = dst_ref[...]                                   # [be]
        local = dst - r * bn
        rows = jax.lax.broadcasted_iota(jnp.int32, (bn, be), 0)
        onehot = (rows == local[None, :]).astype(msgs_ref.dtype)
        o_ref[...] += jax.lax.dot_general(
            onehot, msgs_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(o_ref.dtype)


def scatter_spmm(msgs, dst, n_nodes, *, bn=128, be=256, interpret=False):
    """msgs: [E, D] edge messages; dst: [E] int32 SORTED ascending.

    Returns [n_nodes, D] segment sums.
    """
    E, D = msgs.shape
    bn = min(bn, max(8, n_nodes))
    n_pad = -(-n_nodes // bn) * bn
    e_pad = -(-E // be) * be
    if e_pad > E:
        msgs = jnp.pad(msgs, ((0, e_pad - E), (0, 0)))
        dst = jnp.pad(dst, (0, e_pad - E), constant_values=jnp.int32(2**30))
    nE = e_pad // be
    nR = n_pad // bn
    # per-edge-block dst ranges (scalar prefetch -> SMEM)
    db = dst.reshape(nE, be)
    ranges = jnp.stack([db.min(axis=1), db.max(axis=1)], axis=1)
    ranges = ranges.astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_spmm_kernel, bn=bn, be=be),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nR, nE),
            in_specs=[
                pl.BlockSpec((be,), lambda r, e, rng: (e,)),
                pl.BlockSpec((be, D), lambda r, e, rng: (e, 0)),
            ],
            out_specs=pl.BlockSpec((bn, D), lambda r, e, rng: (r, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_pad, D), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ranges, dst, msgs)
    return out[:n_nodes]
