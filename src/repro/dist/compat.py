"""JAX version compatibility for the distribution layer.

The repo targets the modern mesh API (``jax.make_mesh(..., axis_types=...)``,
``jax.set_mesh``, ``jax.shard_map``, ``jax.sharding.AxisType``) but must also
run on older jax (0.4.x) where those live elsewhere or do not exist:

* ``AxisType``      — tiny stand-in enum when ``jax.sharding`` lacks it
  (the repo only ever uses ``Auto``, which is the 0.4.x default behavior).
* ``make_mesh``     — drops the ``axis_types`` kwarg when unsupported.
* ``use_mesh``      — ``jax.set_mesh`` when available, else the classic
  ``with mesh:`` resource-env context manager.
* ``shard_map``     — ``jax.shard_map`` when available, else
  ``jax.experimental.shard_map.shard_map`` (with ``check_rep=False``: the
  0.4.x replication checker predates several collective patterns used here).

``install()`` additionally publishes these under the modern names on the
``jax`` module itself so drivers and subprocess test scripts written against
the new API run unchanged.  It is idempotent and a no-op on new jax.
Importing ``repro.dist`` (or any of its submodules) installs the shims.
"""
from __future__ import annotations

import contextlib
import enum
import inspect

import jax
import jax.sharding


def _native_axis_type():
    try:
        return jax.sharding.AxisType
    except AttributeError:
        return None


class _AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` on jax < 0.5."""
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = _native_axis_type() or _AxisType

_NATIVE_MAKE_MESH = jax.make_mesh
_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(_NATIVE_MAKE_MESH).parameters)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates ``axis_types`` on old jax."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _MAKE_MESH_TAKES_AXIS_TYPES and axis_types is not None:
        kw["axis_types"] = axis_types
    return _NATIVE_MAKE_MESH(tuple(axis_shapes), tuple(axis_names), **kw)


def use_mesh(mesh):
    """Context manager activating ``mesh`` (``jax.set_mesh`` or legacy)."""
    native = getattr(jax, "set_mesh", None)
    if native is not None and not getattr(native, "_repro_compat", False):
        return native(mesh)

    @contextlib.contextmanager
    def _legacy():
        with mesh:
            yield mesh

    return _legacy()


def shard_map(f, *, mesh=None, in_specs=None, out_specs=None, **kw):
    """Keyword-compatible ``shard_map`` across jax versions."""
    native = getattr(jax, "shard_map", None)
    if native is not None and not getattr(native, "_repro_compat", False):
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw.setdefault("check_rep", False)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` version shim: old jax (<=0.4.x)
    returns a one-dict-per-process LIST, modern jax returns the dict
    itself.  Always returns the (possibly empty) dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _compat(fn):
    fn._repro_compat = True
    return fn


def install() -> None:
    """Publish modern-API names onto ``jax`` for old versions (idempotent)."""
    if _native_axis_type() is None:
        jax.sharding.AxisType = AxisType
    if not _MAKE_MESH_TAKES_AXIS_TYPES and \
            not getattr(jax.make_mesh, "_repro_compat", False):
        jax.make_mesh = _compat(make_mesh)
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _compat(use_mesh)
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _compat(shard_map)


install()
