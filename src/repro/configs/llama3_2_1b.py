"""--arch llama3.2-1b (exact published config; see lm_archs.py)."""
from repro.configs.lm_archs import LLAMA32_1B as CONFIG
from repro.configs.registry import get

BUNDLE = get("llama3.2-1b")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
