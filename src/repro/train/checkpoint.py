"""Fault-tolerant checkpointing (DESIGN §6).

Layout:  <dir>/step_<N>/
            manifest.json     step, leaf index, shapes/dtypes, data hash,
                              mesh shape it was saved under, rng state
            shard_<i>.npz     one file per host-shard group of leaves

Properties required at 1000+-node scale, all implemented here:

* **atomic**   — writes go to ``step_<N>.tmp`` and are renamed only after
  every shard + manifest is fsynced; a crashed writer never corrupts the
  latest complete checkpoint.
* **async**    — ``save_async`` snapshots device arrays to host
  (jax.device_get) and hands the serialization to a writer thread so the
  train loop continues immediately.
* **elastic**  — restore() does not care what mesh the checkpoint was
  saved under: leaves are stored as full logical arrays (host-gathered
  per leaf) and re-sharded onto the *current* mesh at load, so a job can
  restart on a different pod count (the data pipeline is step-keyed, so
  resume is bit-identical — data/pipeline.py).
* **self-validating** — manifest carries per-leaf checksums; restore
  verifies before handing parameters back.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None
        # a crashed/killed writer leaves step_<N>.tmp behind; the rename
        # publish means it is never a valid checkpoint — reclaim the disk
        for stale in self.dir.glob("step_*.tmp"):
            shutil.rmtree(stale, ignore_errors=True)

    # ----------------------------- save -----------------------------

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        """Synchronous atomic save of a pytree of (possibly sharded)
        jax.Arrays or numpy arrays."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)  # snapshot BEFORE returning

        def run():
            # a daemon thread's exception would otherwise vanish into the
            # interpreter's default hook and the save would be SILENTLY
            # missing — capture it and surface from the next wait()/save
            try:
                self._write(step, host_tree, extra or {})
            except BaseException as e:  # noqa: BLE001 — re-raised in wait
                self._exc = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join the in-flight async save; re-raise its exception, if any,
        here on the caller's thread."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise RuntimeError(
                "async checkpoint save failed (raised on the writer "
                "thread)") from exc

    def _write(self, step: int, host_tree, extra: dict) -> None:
        names, leaves, _ = _flatten_with_names(host_tree)
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # shard leaves across files by size budget (~512 MB per shard)
        manifest = dict(step=step, extra=extra, time=time.time(),
                        leaves=[], shards=0)
        budget, cur, cur_bytes, shard_id = 512 << 20, {}, 0, 0

        def flush():
            nonlocal cur, cur_bytes, shard_id
            if cur:
                np.savez(tmp / f"shard_{shard_id}.npz", **cur)
                shard_id += 1
                cur, cur_bytes = {}, 0

        for i, (name, leaf) in enumerate(zip(names, leaves)):
            key = f"leaf_{i}"
            manifest["leaves"].append(dict(
                name=name, key=key, shard=shard_id,
                shape=list(leaf.shape), dtype=str(leaf.dtype),
                sum=_checksum(leaf)))
            cur[key] = leaf
            cur_bytes += leaf.nbytes
            if cur_bytes >= budget:
                flush()
        flush()
        manifest["shards"] = shard_id
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():  # re-save of the same step (e.g. post-resume)
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------- restore ----------------------------

    def all_steps(self) -> list:
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if p.is_dir() and (p / "manifest.json").exists()]

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, tree_like, step: int | None = None,
                shardings=None, verify: bool = True):
        """Restore into the structure of `tree_like`; apply `shardings`
        (same pytree of NamedSharding) for elastic re-sharding onto the
        current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        names, leaves, treedef = _flatten_with_names(tree_like)
        by_name = {l["name"]: l for l in manifest["leaves"]}
        shards = {}
        out = []
        for name, like in zip(names, leaves):
            meta = by_name[name]
            sid = meta["shard"]
            if sid not in shards:
                shards[sid] = np.load(d / f"shard_{sid}.npz")
            arr = shards[sid][meta["key"]]
            if verify and _checksum(arr) != meta["sum"]:
                raise IOError(f"checksum mismatch for {name} @ step {step}")
            out.append(arr)
        restored = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        return restored, manifest["extra"], step
