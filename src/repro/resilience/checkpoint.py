"""Durable streaming state: engine <-> train/checkpoint glue (DESIGN §9).

``StreamingEngine.checkpoint`` / ``.restore`` route the full
``MachineState`` pytree — plus the stream cursor and a config
fingerprint — through the seed's :class:`repro.train.checkpoint.
Checkpointer` (atomic tmp+rename publish, async writer thread, per-leaf
checksums, elastic re-shard on load).  This module holds the small
pieces that are not engine methods: the fingerprint and the manifest
schema helpers.

The fingerprint covers every ``EngineConfig`` field (including the
nested ``FaultPlan``): restoring under a different config would
reinterpret addresses/queue layouts silently, so ``restore`` refuses a
mismatch unless explicitly told ``strict=False`` (e.g. to inspect a
checkpoint post-mortem).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

CKPT_KIND = "cca_stream"


def config_fingerprint(cfg) -> str:
    """Stable 16-hex-digit digest of every config field (nested
    dataclasses included)."""
    d = dataclasses.asdict(cfg)
    blob = json.dumps(d, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def stream_manifest(engine) -> dict:
    """The ``extra`` dict saved next to the state leaves: everything the
    host driver needs to resume mid-stream bit-exactly."""
    return dict(
        kind=CKPT_KIND,
        config=config_fingerprint(engine.cfg),
        app=engine.app.name,
        stream_pos=engine.stream_pos,
        total_cycles=engine.total_cycles,
        totals=dict(engine.totals),
    )
