"""The cycle engine: composes routing, execution and ingestion into one
pure ``state -> state`` step, runs it to quiescence, and exposes the
streaming-increment API used by the experiments.

Cycle order (all fixed-shape, fully vectorized over the cell grid):

  1. hop_stage      channel heads advance one link (YX DOR, backpressure)
  2. staging        active actions stage one ``propagate`` message
  3. phase0         idle cells pop one action and run its compute step
  4. io_stage       IO cells inject the next streamed edge

Quiescence (the paper's Terminator object): no queued actions, no channel
occupancy, no active action, no deferred future tasks, no pending IO.
On a real pod this is a tree all-reduce of the pending counters; here it is
literally ``jnp.sum`` inside the jitted step — GSPMD lowers it to
``all-reduce`` when the grid is sharded (see the dry-run HLO).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alloc import rhizome_rcs
from repro.core.apps import APPS, DiffusionApp
from repro.core.config import EngineConfig
from repro.core.exec_stage import phase0_stage, staging_stage
from repro.core.ingest import io_stage, load_stream
from repro.core.routing import hop_stage
from repro.core.state import (MachineState, init_state, root_addr,
                              self_cell_grid)


class CycleStats(NamedTuple):
    active: jax.Array      # cells doing compute/staging work this cycle
    in_flight: jax.Array   # messages sitting in channels
    backlog: jax.Array     # queued actions
    hops: jax.Array        # link traversals this cycle
    quiescent: jax.Array   # bool


def _rc(cfg: EngineConfig):
    rows = jnp.arange(cfg.height, dtype=jnp.int32)[:, None]
    cols = jnp.arange(cfg.width, dtype=jnp.int32)[None, :]
    return (jnp.broadcast_to(rows, (cfg.height, cfg.width)),
            jnp.broadcast_to(cols, (cfg.height, cfg.width)))


def quiescent(st: MachineState) -> jax.Array:
    return ((jnp.sum(st.aq_n) == 0) & (jnp.sum(st.ch_n) == 0)
            & ~jnp.any(st.cvalid) & (jnp.sum(st.fq_n) == 0)
            & ~jnp.any(st.fwd_pending)
            & (jnp.sum(st.io_n - st.io_pos) == 0))


def cycle_step(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    rows, cols = _rc(cfg)
    busy0 = st.cvalid
    st, hops = hop_stage(cfg, st, rows, cols)
    st, active_a = staging_stage(cfg, app, st, rows, cols)
    st, popped = phase0_stage(cfg, app, st, rows, cols, busy0)
    st = io_stage(cfg, st, rows, cols)
    st = st._replace(cycle=st.cycle + 1,
                     stat_hops=st.stat_hops + hops)
    stats = CycleStats(
        active=jnp.sum((active_a | popped).astype(jnp.int32)),
        in_flight=jnp.sum(st.ch_n), backlog=jnp.sum(st.aq_n),
        hops=hops, quiescent=quiescent(st))
    return st, stats


def run_chunk_body(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    """Un-jitted fixed-length chunk (dry-run / roofline entry point: the
    caller jits this with the production-mesh shardings)."""
    def body(s, _):
        s2, _ = cycle_step(cfg, app, s)
        return s2, None
    st, _ = jax.lax.scan(body, st, None, length=cfg.chunk)
    return st


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=2)
def run_chunk(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    """Scan `cfg.chunk` cycles; freeze once quiescent (identity cycles)."""
    def body(s, _):
        done = quiescent(s)
        s2, stats = cycle_step(cfg, app, s)
        s = jax.tree.map(lambda a, b: jnp.where(done, a, b), s, s2)
        return s, stats
    return jax.lax.scan(body, st, None, length=cfg.chunk)


def run_to_quiescence_while(cfg: EngineConfig, app: DiffusionApp,
                            st: MachineState, max_cycles=None):
    """Pure lax.while_loop runner (no traces) — the dry-run/roofline path."""
    mc = jnp.int32(max_cycles or cfg.max_cycles)
    start = st.cycle

    def cond(s):
        return (~quiescent(s)) & (s.cycle - start < mc)

    def body(s):
        s2, _ = cycle_step(cfg, app, s)
        return s2

    return jax.lax.while_loop(cond, body, st)


@dataclasses.dataclass
class IncrementResult:
    cycles: int
    active_per_cycle: np.ndarray
    in_flight_per_cycle: np.ndarray
    hops: int
    execs: int
    stalls: int
    allocs: int


class StreamingEngine:
    """Host-side driver: the accelerator-style main() of paper Listing 1."""

    def __init__(self, cfg: EngineConfig, app: str | DiffusionApp = "bfs"):
        self.cfg = cfg
        self.app = APPS[app] if isinstance(app, str) else app
        cfg = dataclasses.replace(cfg, n_vals=self.app.n_vals)
        self.cfg = cfg
        self.state = init_state(cfg, init_vals=self.app.init_val)
        self.total_cycles = 0
        self.totals = dict(hops=0, execs=0, stalls=0, allocs=0)

    # -- seeding (e.g. the BFS source vertex gets level 0 pre-stream) --
    def seed(self, vid: int, value: float, val_idx: int = 0):
        """Host-write a value into EVERY rhizome root of ``vid`` so the
        co-equal roots start value-synced (DESIGN §4.5)."""
        cfg = self.cfg
        vals = self.state.vals
        for k in range(cfg.rhizome_cap):
            r, c, s = rhizome_rcs(cfg, vid, k)
            vals = vals.at[r, c, s, val_idx].set(value)
        self.state = self.state._replace(vals=vals)

    # -- stream one increment of edges and run to quiescence --
    def run_increment(self, edges: np.ndarray,
                      max_cycles: int | None = None) -> IncrementResult:
        cfg = self.cfg
        self.state, spill = load_stream(cfg, self.state, edges)
        act, flt = [], []
        hops = execs = stalls = allocs = 0
        cycles = 0
        limit = max_cycles or cfg.max_cycles
        zero_stats = self.state._replace(stat_hops=jnp.int32(0),
                                         stat_exec=jnp.int32(0),
                                         stat_stall=jnp.int32(0),
                                         stat_allocs=jnp.int32(0))
        self.state = zero_stats
        last_exec, no_progress = 0, 0
        while cycles < limit:
            self.state, stats = run_chunk(cfg, self.app, self.state)
            q = np.asarray(stats.quiescent)
            a = np.asarray(stats.active)
            f = np.asarray(stats.in_flight)
            if q.any():
                n = int(np.argmax(q))  # first quiescent cycle in chunk
                act.append(a[:n]); flt.append(f[:n])
                cycles += n
                if len(spill):
                    # io_stream_cap overflow residue: the loaded prefix is
                    # fully consumed at quiescence, so the next pass has
                    # the whole IO capacity again (DESIGN §4.2)
                    self.state, spill = load_stream(cfg, self.state, spill)
                    continue
                break
            act.append(a); flt.append(f)
            cycles += cfg.chunk
            # Message-dependent-deadlock detector: YX DOR keeps the
            # NETWORK acyclic, but the execute stage (pop -> emit ->
            # channel) can close a protocol cycle when buffers are sized
            # below the workload's dependency depth.  Fail loudly with
            # sizing advice instead of silently dropping work.
            e = int(self.state.stat_exec)
            no_progress = no_progress + 1 if e == last_exec else 0
            last_exec = e
            if no_progress >= 8:
                raise RuntimeError(
                    "engine livelock: no action executed for "
                    f"{8 * cfg.chunk} cycles with work pending. "
                    "Increase chan_cap (>=4) and/or queue_cap "
                    f"(>= aq_reserve+sys_reserve+8 = "
                    f"{cfg.aq_reserve + cfg.sys_reserve + 8}) — see "
                    "DESIGN.md §4.2 buffer-sizing rule.")
        if len(spill):
            # never drop work silently: the cycle limit ran out before the
            # spilled residue could be re-loaded and ingested
            raise RuntimeError(
                f"cycle limit {limit} exhausted with {len(spill)} spilled "
                "edges not yet ingested; raise max_cycles or io_stream_cap "
                "(DESIGN.md §4.2).")
        hops = int(self.state.stat_hops)
        execs = int(self.state.stat_exec)
        stalls = int(self.state.stat_stall)
        allocs = int(self.state.stat_allocs)
        self.total_cycles += cycles
        for k, v in zip(("hops", "execs", "stalls", "allocs"),
                        (hops, execs, stalls, allocs)):
            self.totals[k] += v
        return IncrementResult(
            cycles=cycles,
            active_per_cycle=np.concatenate(act) if act else np.zeros(0, np.int32),
            in_flight_per_cycle=np.concatenate(flt) if flt else np.zeros(0, np.int32),
            hops=hops, execs=execs, stalls=stalls, allocs=allocs)

    # -- read back application values from the vertex objects --
    def values(self, n: int | None = None, val_idx: int = 0) -> np.ndarray:
        """Min-reduce over every rhizome root of each vertex.

        The canonical root always holds the tightest value (all external
        relaxes land there; siblings only receive its snapshots), so for
        the bundled monotone-min apps the reduce equals the canonical
        value — kept as a reduce so readback stays correct even mid-run.
        """
        cfg = self.cfg
        n = n or cfg.n_vertices
        vids = np.arange(n, dtype=np.int64)
        vals = np.asarray(self.state.vals[..., val_idx])
        out = None
        for k in range(cfg.rhizome_cap):
            r, c, s = rhizome_rcs(cfg, vids, k)
            v = vals[r, c, s]
            out = v if out is None else self.app.combine(out, v)
        return out

    def vertex_object_stats(self) -> dict:
        """Diagnostics over the hierarchical vertex objects: ghost usage +
        locality (validates Fig. 5 policies) plus rhizome fan-out and the
        spread of co-equal roots over the mesh (DESIGN §4.5)."""
        cfg = self.cfg
        st = self.state
        gs = np.asarray(st.gstate)
        ga = np.asarray(st.gaddr)
        used = int(np.sum(np.asarray(st.nfree) - cfg.primary_slots))
        out = dict(ghosts=used, mean_hops=0.0, max_hops=0,
                   rhizomes=0, multi_root_vertices=0, max_fanout=1,
                   mean_rhizome_hops=0.0)
        have = gs == 2
        if have.any():
            rr, cc, _ = np.nonzero(have)
            tgt_cell = ga[have] // cfg.slots
            tr, tc = tgt_cell // cfg.width, tgt_cell % cfg.width
            d = np.abs(rr - tr) + np.abs(cc - tc)
            out.update(mean_hops=float(d.mean()), max_hops=int(d.max()))
        if cfg.rhizome_cap > 1:
            on = np.asarray(st.rhz_on)          # [H,W,S]
            vids = np.arange(cfg.n_vertices, dtype=np.int64)
            fan = np.ones(cfg.n_vertices, np.int64)
            dists = []
            r0, c0, _ = rhizome_rcs(cfg, vids, 0)
            for k in range(1, cfg.rhizome_cap):
                r, c, s = rhizome_rcs(cfg, vids, k)
                act = on[r, c, s]
                fan += act
                if act.any():
                    dists.append((np.abs(r - r0) + np.abs(c - c0))[act])
            out.update(
                rhizomes=int(fan.sum() - cfg.n_vertices),
                multi_root_vertices=int((fan > 1).sum()),
                max_fanout=int(fan.max()),
                mean_rhizome_hops=(float(np.concatenate(dists).mean())
                                   if dists else 0.0))
        return out

    def ghost_chain_stats(self) -> dict:
        """Back-compat alias of :meth:`vertex_object_stats` (pre-rhizome
        name); returns the same dict."""
        return self.vertex_object_stats()
