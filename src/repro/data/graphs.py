"""Graph batch builders: synthetic graphs per shape spec, the GraphCast
multimesh, disjoint-union batching for molecule sets, and the neighbor
sampler feeding ``minibatch_lg`` (a real fanout sampler — part of the
system, not a stub).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.models.gnn import Graph, GNNConfig, icosphere_sizes


def graphcast_sizes(cfg: GNNConfig, n_grid: int) -> dict:
    n_mesh, e_mesh = icosphere_sizes(cfg.mesh_refinement)
    return dict(n_mesh=n_mesh, e_mesh=e_mesh,
                e_g2m=3 * n_grid, e_m2g=3 * n_grid)


def _rand_edges(rng, n, e, sorted_dst=True):
    src = rng.integers(0, n, e, dtype=np.int64)
    dst = rng.integers(0, n, e, dtype=np.int64)
    if sorted_dst:
        o = np.argsort(dst, kind="stable")
        src, dst = src[o], dst[o]
    return np.stack([src, dst]).astype(np.int32)


def build_graph(cfg: GNNConfig, spec, rng=None) -> Graph:
    """Materialize a concrete random graph batch for a shape spec.

    Only call with small/smoke sizes; big cells go through input_specs().
    """
    rng = rng or np.random.default_rng(0)
    d = dict(spec.dims)
    kind = spec.kind
    if kind == "gnn_batched":
        b, n1, e1 = d["batch"], d["n_nodes"], d["n_edges"]
        n, e = b * n1, b * e1
        # disjoint union: edges stay within each small graph
        ei = []
        for g in range(b):
            eg = _rand_edges(rng, n1, e1, sorted_dst=False) + g * n1
            ei.append(eg)
        edge_index = np.concatenate(ei, axis=1)
        o = np.argsort(edge_index[1], kind="stable")
        edge_index = edge_index[:, o]
    else:
        n, e = d["n_nodes"], d["n_edges"]
        if kind == "gnn_minibatch":
            n, e = sampled_subgraph_sizes(d)
        edge_index = _rand_edges(rng, n, e)
    x = rng.standard_normal((n, d["d_feat"]), dtype=np.float32)
    g = Graph(x=jnp.asarray(x), edge_index=jnp.asarray(edge_index))
    if cfg.kind == "graphcast":
        gs = graphcast_sizes(cfg, n)
        g = g._replace(
            mesh_edge_index=jnp.asarray(
                _rand_edges(rng, gs["n_mesh"], gs["e_mesh"])),
            g2m_edge_index=jnp.asarray(np.stack([
                rng.integers(0, n, gs["e_g2m"]),
                np.sort(rng.integers(0, gs["n_mesh"], gs["e_g2m"]))
            ]).astype(np.int32)),
            m2g_edge_index=jnp.asarray(np.stack([
                rng.integers(0, gs["n_mesh"], gs["e_m2g"]),
                np.sort(rng.integers(0, n, gs["e_m2g"]))
            ]).astype(np.int32)))
    return g


def sampled_subgraph_sizes(dims: dict) -> tuple[int, int]:
    """Padded (nodes, edges) of a fanout-sampled block set."""
    b = dims["batch_nodes"]
    nodes, edges, frontier = b, 0, b
    for f in dims["fanout"]:
        edges += frontier * f
        frontier = frontier * f
        nodes += frontier
    return nodes, edges


# ---------------- neighbor sampler (GraphSAGE-style fanout) ----------------

class NeighborSampler:
    """Uniform fanout sampler over a CSR adjacency (host-side, numpy).

    Produces fixed-shape (padded) subgraph batches: seeds first, then each
    hop's sampled neighbors; edges point child -> parent (message flows
    toward the seeds, matching aggregation direction).
    """

    def __init__(self, n_nodes: int, edge_index: np.ndarray, seed: int = 0):
        src, dst = edge_index
        order = np.argsort(dst, kind="stable")
        self.nbr = src[order].astype(np.int64)
        counts = np.bincount(dst, minlength=n_nodes)
        self.ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.n = n_nodes
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray, fanout) -> dict:
        nodes = [seeds.astype(np.int64)]
        edges_src, edges_dst = [], []
        frontier = seeds.astype(np.int64)
        base = 0
        for f in fanout:
            deg = self.ptr[frontier + 1] - self.ptr[frontier]
            # sample f neighbors (with replacement; isolated -> self)
            r = self.rng.integers(0, 1 << 62, size=(len(frontier), f))
            idx = self.ptr[frontier][:, None] + r % np.maximum(deg, 1)[:, None]
            nb = np.where(deg[:, None] > 0, self.nbr[idx],
                          frontier[:, None])
            child_base = sum(len(x) for x in nodes)
            parents_local = np.arange(base, base + len(frontier))
            edges_src.append((child_base
                              + np.arange(nb.size)).astype(np.int64))
            edges_dst.append(np.repeat(parents_local, f))
            nodes.append(nb.reshape(-1))
            base += len(frontier)
            frontier = nb.reshape(-1)
        local_nodes = np.concatenate(nodes)
        ei = np.stack([np.concatenate(edges_src),
                       np.concatenate(edges_dst)]).astype(np.int32)
        return dict(node_ids=local_nodes, edge_index=ei,
                    n_seeds=len(seeds))
