"""Paper-experiment benchmarks: one function per paper table/figure.

Default scale is CPU-friendly (the simulator is cycle-exact, so all
RELATIVE effects — edge vs. snowball shapes, vicinity vs. random,
per-increment growth — reproduce at reduced vertex counts).  Pass
--scale=paper for the full 50K/1M-edge runs (minutes on CPU).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import EngineConfig, StreamingEngine
from repro.core.energy import DEFAULT as ENERGY
from repro.core.reference import bfs_levels
from repro.graph.streams import StreamSpec, make_stream

SCALES = {
    "ci": dict(n_vertices=2000, n_edges=20_000),
    "mid": dict(n_vertices=10_000, n_edges=100_000),
    "paper": dict(n_vertices=50_000, n_edges=1_000_000),
}


def _engine(n_vertices: int, app: str, allocator="vicinity",
            chunk=512, n_edges: int = 0) -> StreamingEngine:
    # ghost capacity must cover the spilled edge blocks: ~E/edge_cap
    # RPVO blocks across 1024 cells, x2 for placement skew (exhausting
    # ghost slots livelocks the allocate forwarding chain — DESIGN §4.2)
    ghosts = max(64, 2 * n_edges // (8 * 1024), 3 * n_vertices // 1024)
    cfg = EngineConfig(height=32, width=32, n_vertices=n_vertices,
                       edge_cap=8, ghost_slots=ghosts,
                       queue_cap=64, chan_cap=16, futq_cap=16,
                       io_stream_cap=2 ** 21, chunk=chunk,
                       allocator=allocator)
    eng = StreamingEngine(cfg, app)
    if app != "ingest_only":
        eng.seed(0, 0.0)
    return eng


_CACHE: dict = {}


def run_stream(app: str, sampling: str, scale: str, allocator="vicinity",
               verify=False, collect_traces=False):
    """``collect_traces=False`` rides the engine's sync-free fast path
    (one jit call per increment, scalar totals only) — the default for
    every benchmark except the activation traces of Fig. 6/7."""
    key = (app, sampling, scale, allocator, collect_traces)
    if key in _CACHE and not verify:
        return _CACHE[key]
    if not collect_traces and not verify:
        # a traced run of the same stream satisfies untraced consumers
        # (identical totals — pinned by test_collect_traces_equivalence)
        traced = _CACHE.get((app, sampling, scale, allocator, True))
        if traced is not None:
            return traced
    spec = StreamSpec(increments=10, sampling=sampling, seed=1,
                      **SCALES[scale])
    incs = make_stream(spec)
    eng = _engine(spec.n_vertices, app, allocator, n_edges=spec.n_edges)
    rows = []
    for i, e in enumerate(incs):
        r = eng.run_increment(e, max_cycles=2_000_000,
                              collect_traces=collect_traces)
        rows.append(dict(increment=i, edges=len(e), cycles=r.cycles,
                         execs=r.execs, hops=r.hops, allocs=r.allocs,
                         stalls=r.stalls,
                         active=r.active_per_cycle))
    if verify and app == "bfs":
        want = bfs_levels(spec.n_vertices, np.concatenate(incs), 0)
        got = eng.values(spec.n_vertices)
        assert (got == want).all(), "BFS mismatch vs NetworkX"
    _CACHE[key] = (rows, eng)
    return rows, eng


# ------------------- Fig 8/9: cycles per increment -------------------

def bench_cycles_per_increment(scale="ci", sampling="edge"):
    """Paper Fig. 8/9: per-increment cycles, ingestion-only vs +BFS."""
    t0 = time.time()
    ing, _ = run_stream("ingest_only", sampling, scale)
    bfs, _ = run_stream("bfs", sampling, scale, verify=(scale == "ci"))
    out = []
    for a, b in zip(ing, bfs):
        out.append(dict(increment=a["increment"], edges=a["edges"],
                        ingest_cycles=a["cycles"],
                        ingest_bfs_cycles=b["cycles"]))
    return out, time.time() - t0


# ------------------- Table 2: energy & time -------------------

def bench_energy(scale="ci"):
    """Paper Table 2 analogue: energy (uJ) + time (us) @ 1 GHz."""
    rows = []
    for sampling in ("edge", "snowball"):
        for app, label in (("ingest_only", "Ingestion"),
                           ("bfs", "Ingestion & BFS")):
            data, eng = run_stream(app, sampling, scale)
            cycles = sum(r["cycles"] for r in data)
            hops = sum(r["hops"] for r in data)
            execs = sum(r["execs"] for r in data)
            allocs = sum(r["allocs"] for r in data)
            injects = sum(r["edges"] for r in data)
            rows.append(dict(
                sampling=sampling, mode=label,
                energy_uj=round(ENERGY.estimate_uj(
                    hops=hops, execs=execs, allocs=allocs,
                    injects=injects), 1),
                time_us=round(ENERGY.cycles_to_us(cycles), 2),
                cycles=cycles))
    return rows


# ------------------- Fig 5: allocator policies -------------------

def bench_allocator(scale="ci"):
    """Vicinity vs random ghost allocation: locality + cycle cost."""
    rows = []
    for alloc in ("vicinity", "random"):
        data, eng = run_stream("bfs", "edge", scale, allocator=alloc)
        stats = eng.vertex_object_stats()
        rows.append(dict(allocator=alloc,
                         cycles=sum(r["cycles"] for r in data),
                         hops=sum(r["hops"] for r in data),
                         ghosts=stats["ghosts"],
                         mean_ghost_hops=round(stats["mean_hops"], 2),
                         max_ghost_hops=stats["max_hops"]))
    return rows


# ------------------- Fig 6/7: activation traces -------------------

def bench_activation(scale="ci", sampling="edge", out_npz=None):
    """Per-cycle active-cell counts (chip occupancy traces)."""
    ing, _ = run_stream("ingest_only", sampling, scale, collect_traces=True)
    bfs, _ = run_stream("bfs", sampling, scale, collect_traces=True)
    trace_i = np.concatenate([r["active"] for r in ing])
    trace_b = np.concatenate([r["active"] for r in bfs])
    if out_npz:
        np.savez(out_npz, ingest=trace_i, ingest_bfs=trace_b)
    summarize = lambda t: dict(
        cycles=len(t), mean_active=round(float(t.mean()), 1),
        peak_active=int(t.max()),
        mean_util_pct=round(100 * float(t.mean()) / 1024, 2))
    return dict(ingest=summarize(trace_i), ingest_bfs=summarize(trace_b))


# ------------------- rhizome vs chain on skewed streams -------------------

SKEW_SCALES = {
    "ci": dict(height=8, width=8, n_vertices=256, n_edges=4096),
    "mid": dict(height=16, width=16, n_vertices=2048, n_edges=32_768),
    "paper": dict(height=32, width=32, n_vertices=16_384, n_edges=262_144),
}


def bench_skew(scale="ci", rhizome_caps=(1, 2, 4), verify=True):
    """Power-law (R-MAT) stream: serial ghost chain (rhizome_cap=1) vs
    multi-root rhizome vertex objects (DESIGN §4.5).

    The R-MAT hubs exceed ``edge_cap`` many times over, so the chain
    design serializes every hub insert and bfs broadcast; rhizomes shard
    the hub over co-equal roots.  Reports cycles-to-quiescence per cap,
    with exact host-reference verification.
    """
    p = SKEW_SCALES[scale]
    edge_cap = 8
    spec = StreamSpec(n_vertices=p["n_vertices"], n_edges=p["n_edges"],
                      increments=4, kind="rmat", seed=2)
    incs = make_stream(spec)
    allv = np.concatenate(incs)
    deg = np.bincount(allv[:, 0], minlength=p["n_vertices"])
    want = bfs_levels(p["n_vertices"], allv, 0) if verify else None
    rows = []
    for R in rhizome_caps:
        cfg = EngineConfig(
            height=p["height"], width=p["width"],
            n_vertices=p["n_vertices"], edge_cap=edge_cap,
            ghost_slots=max(64, 4 * p["n_edges"]
                            // (edge_cap * p["height"] * p["width"])),
            # virtual lanes (DESIGN §7) carry the R=1 hub pile-up at the
            # normal queue sizing — the pre-lane 192 oversize workaround
            # is gone (lanes>=2 complete at LANES_QUEUE_CAP=48, see
            # bench_lanes / results/bench_lanes.json)
            queue_cap=LANES_QUEUE_CAP, chan_cap=32, futq_cap=8,
            io_stream_cap=2 ** 20, chunk=512, rhizome_cap=R, lanes=2)
        eng = StreamingEngine(cfg, "bfs")
        eng.seed(0, 0.0)
        cycles = hops = stalls = 0
        for e in incs:
            r = eng.run_increment(e, max_cycles=4_000_000)
            cycles += r.cycles
            hops += r.hops
            stalls += r.stalls
        if verify:
            got = eng.values(p["n_vertices"])
            assert (got == want).all(), \
                f"BFS mismatch vs NetworkX at rhizome_cap={R}"
        s = eng.vertex_object_stats()
        rows.append(dict(rhizome_cap=R, cycles=cycles, hops=hops,
                         stalls=stalls, max_degree=int(deg.max()),
                         degree_over_edge_cap=round(
                             float(deg.max()) / edge_cap, 1),
                         rhizomes=s["rhizomes"],
                         multi_root_vertices=s["multi_root_vertices"],
                         max_fanout=s["max_fanout"],
                         ghosts=s["ghosts"]))
    return rows


# ------------- virtual lanes vs the §4.2 hub-convergent deadlock ----------

LANES_QUEUE_CAP = 48      # the normal queue sizing, shared with
                          # bench_skew: lanes=1 needs a 4x oversize
                          # (queue_cap=192) to stay alive on this stream
                          # (DESIGN §4.2); the lane protocol (§7)
                          # completes it at 48 (and below)


def bench_lanes(scale="ci", lanes_list=(1, 2, 4), verify=True,
                out_json="results/bench_lanes.json"):
    """Virtual-lane flow control on the R-MAT hub-convergent stream
    (DESIGN §7): the same skewed stream as :func:`bench_skew`, but at the
    pre-oversize ``queue_cap`` — small enough that the single-FIFO
    channel machine (``lanes=1``) hits the §4.2 head-of-line deadlock.

    Records cycles/stalls per lane count into ``results/bench_lanes.json``
    (plus the oversized ``lanes=1`` baseline for the cycle comparison).
    ``lanes=1`` is EXPECTED to livelock; any ``lanes >= 2`` cell that
    livelocks or mismatches the reference fails loudly — this is the CI
    ``lanes-smoke`` gate.
    """
    import json
    import pathlib

    p = SKEW_SCALES[scale]
    spec = StreamSpec(n_vertices=p["n_vertices"], n_edges=p["n_edges"],
                      increments=4, kind="rmat", seed=2)
    incs = make_stream(spec)
    allv = np.concatenate(incs)
    deg = np.bincount(allv[:, 0], minlength=p["n_vertices"])
    want = bfs_levels(p["n_vertices"], allv, 0) if verify else None

    def _cfg(lanes, queue_cap):
        return EngineConfig(
            height=p["height"], width=p["width"],
            n_vertices=p["n_vertices"], edge_cap=8,
            ghost_slots=max(64, 4 * p["n_edges"]
                            // (8 * p["height"] * p["width"])),
            queue_cap=queue_cap, chan_cap=32, futq_cap=8,
            io_stream_cap=2 ** 20, chunk=512, lanes=lanes)

    def _run(cfg):
        eng = StreamingEngine(cfg, "bfs")
        eng.seed(0, 0.0)
        cycles = stalls = 0
        try:
            for e in incs:
                r = eng.run_increment(e, max_cycles=4_000_000)
                cycles += r.cycles
                stalls += r.stalls
        except RuntimeError as ex:
            if "livelock" not in str(ex):
                raise
            return dict(status="livelock", cycles=None, stalls=None)
        if verify:
            got = eng.values(p["n_vertices"])
            assert (got == want).all(), \
                f"BFS mismatch vs NetworkX at lanes={cfg.lanes}"
        return dict(status="ok", cycles=cycles, stalls=stalls)

    rows = []
    for L in lanes_list:
        r = _run(_cfg(L, LANES_QUEUE_CAP))
        r.update(lanes=L, queue_cap=LANES_QUEUE_CAP,
                 max_degree=int(deg.max()))
        rows.append(r)
    # the pre-lane workaround for the same stream: lanes=1, queue_cap 4x
    base = _run(_cfg(1, 192))
    base.update(lanes=1, queue_cap=192)

    bad = [r["lanes"] for r in rows if r["lanes"] >= 2
           and r["status"] != "ok"]
    if bad or base["status"] != "ok":
        raise SystemExit(
            f"lanes-smoke gate: livelock with lanes in {bad} "
            f"(baseline {base['status']}) — the §7 protocol regressed")

    out = dict(scale=scale, grid=f'{p["height"]}x{p["width"]}',
               n_edges=p["n_edges"], rows=rows, oversize_baseline=base)
    path = pathlib.Path(out_json)
    path.parent.mkdir(parents=True, exist_ok=True)
    data = json.loads(path.read_text()) if path.exists() else {}
    data[f"lanes_{scale}"] = out
    path.write_text(json.dumps(data, indent=1))
    return rows, base


# ------------------- engine wall-clock throughput -------------------

def bench_engine_throughput(scale="ci"):
    """Simulator performance (the §Perf hillclimb metric on CPU):
    cell-cycles per wall second."""
    spec = StreamSpec(increments=2, sampling="edge", seed=2, **SCALES[scale])
    incs = make_stream(spec)
    eng = _engine(spec.n_vertices, "bfs")
    eng.run_increment(incs[0][:1000], max_cycles=20_000)  # warm the jit
    t0 = time.time()
    r = eng.run_increment(incs[1], max_cycles=2_000_000)
    dt = time.time() - t0
    return dict(cycles=r.cycles, wall_s=round(dt, 2),
                cyc_per_s=round(r.cycles / dt, 1),
                cell_cycles_per_s=round(r.cycles / dt * 1024, 0))
