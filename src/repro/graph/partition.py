"""Owner-partitioned COO edges: the GNN collective optimization
(EXPERIMENTS.md §Perf, gcn-cora cell).

Edges are bucketed by the shard that OWNS their destination node (node
blocks are contiguous ranges), each bucket padded to the common max so
the flat edge array shards evenly.  Message passing then needs exactly
ONE collective per layer — the bf16 all-gather of node features — and the
scatter-add is purely local (no all-reduce): the paper's "work to data"
principle applied to bulk message passing.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map

PAD_DST = np.int32(2 ** 30)


def partition_edges(edge_index: np.ndarray, n_nodes: int,
                    n_shards: int) -> np.ndarray:
    """[2, E] COO -> [2, E_pad] bucketed by dst owner, equal buckets."""
    src, dst = np.asarray(edge_index)
    n_loc = -(-n_nodes // n_shards)
    owner = dst // n_loc
    order = np.argsort(owner, kind="stable")
    src, dst, owner = src[order], dst[order], owner[order]
    counts = np.bincount(owner, minlength=n_shards)
    emax = int(counts.max())
    out = np.full((2, n_shards * emax), PAD_DST, np.int32)
    pos = 0
    for s in range(n_shards):
        c = counts[s]
        out[0, s * emax:s * emax + c] = src[pos:pos + c]
        out[1, s * emax:s * emax + c] = dst[pos:pos + c]
        pos += c
    return out


def spmm_partitioned(x, edge_index, n_nodes, coeff=None, mesh=None,
                     axes=("data", "model")):
    """A @ X with owner-partitioned edges under shard_map.

    x: [N, D] sharded over axes; edge_index: [2, E_pad] bucketed so the
    e-th shard's edges all target the e-th node block.  One bf16
    all-gather of x per call; scatter-add entirely local.
    """
    nsh = int(np.prod([mesh.shape[a] for a in axes]))
    N, D = x.shape
    n_loc = N // nsh

    def local(x_l, ei_l, coeff_l):
        xf = jax.lax.all_gather(x_l.astype(jnp.bfloat16), axes, axis=0,
                                tiled=True)
        src, dst = ei_l[0], ei_l[1]
        m = xf[jnp.clip(src, 0, N - 1)].astype(jnp.float32)
        if coeff_l is not None:
            m = m * coeff_l[:, None]
        idx = (jax.lax.axis_index(axes[0]) * mesh.shape[axes[1]]
               + jax.lax.axis_index(axes[1]))
        local_dst = dst - idx * n_loc   # out-of-range (incl. pad) dropped
        out = jnp.zeros((n_loc, D), jnp.float32)
        return out.at[local_dst].add(m, mode="drop")

    specs = (P(axes, None), P(None, axes),
             P(axes) if coeff is not None else None)
    args = (x, edge_index) + ((coeff,) if coeff is not None else ())
    if coeff is None:
        def local2(x_l, ei_l):
            return local(x_l, ei_l, None)
        return shard_map(local2, mesh=mesh, in_specs=specs[:2],
                         out_specs=P(axes, None))(*args)
    return shard_map(local, mesh=mesh, in_specs=specs,
                     out_specs=P(axes, None))(*args)
