"""Action execution: the diffusive programming model's compute stage.

AM-CCA executes **one operation per cell per cycle**: either a computing
instruction (the action body) or the creation/staging of one new message
via ``propagate`` (paper §4).  We model this faithfully with per-cell
active-action registers: an action occupies its cell for ``1 + T`` cycles —
one mutate cycle (phase 0) plus one cycle per emission, with backpressure
stalls when the target buffer is full.

Handlers implemented (paper Listings 4-6 + system actions of Fig. 3/4,
plus the rhizome protocol of DESIGN §4.5):

  OP_INSERT_EDGE  insert-edge-action with the full ghost/future protocol;
                  at an inactive rhizome root it defers on the slot's
                  future queue and requests activation (OP_LINK_RHIZOME)
  OP_APP          the application action (bfs-action et al.); a changed
                  relax at a canonical root with linked siblings broadcasts
                  OP_RHIZOME_FWD to every co-equal root in parallel
  OP_ALLOC        remote ghost allocation (vicinity/random allocator)
  OP_SET_FUTURE   continuation return: set future, drain deferred queue
  OP_RHIZOME_FWD  sibling value sync: relax locally, diffuse along the
                  local edge shard + own ghost chain; activates a pending
                  rhizome root and drains its deferred inserts (link-ack)
  OP_LINK_RHIZOME activation request at the canonical root: mark the
                  vertex multi-root and ack with the current value

Implementation note (§Perf, cca cell): every slot access is a one-hot
``where`` over the slot axis — never a scatter/gather with index arrays —
so GSPMD partitions each cycle over the sharded cell grid with zero
collectives beyond the routing permutes and the quiescence all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rings
from repro.core.alloc import (choose_alloc_cell, rhizome_addr,
                              rhizome_owner_vid)
from repro.core.apps import DiffusionApp, neutral_vec
from repro.core.config import EngineConfig
from repro.core.msg import (MSG_WORDS, OP_ALLOC, OP_APP, OP_INSERT_EDGE,
                            OP_LINK_RHIZOME, OP_REPAIR, OP_RHIZOME_FWD,
                            OP_SET_FUTURE, TB_AQ_SELF, f2i, i2f, make_msg,
                            make_qmsg, msg_qvals, msg_seal, pad_msg,
                            qsel_mask, seal_msg)
from repro.core.routing import deliver, msg_lane, yx_target_buffer
from repro.core.state import (G_NULL, G_PENDING, G_SET, MachineState,
                              TM_ALLOC, TM_BCAST, TM_EXEC, TM_PARK, TM_STAGE,
                              TM_STALL)


def _oh(idx, n, mask=None):
    """One-hot [..., n] selector; optionally masked."""
    oh = jnp.arange(n, dtype=jnp.int32) == idx[..., None]
    if mask is not None:
        oh = oh & mask[..., None]
    return oh


def _expand(oh, arr):
    """Reshape a [H,W,S] selector to broadcast against arr [H,W,S,...]."""
    return oh.reshape(oh.shape + (1,) * (arr.ndim - oh.ndim))


def sel(arr, slot):
    """arr[II, JJ, slot] as one-hot reduce.  arr: [H,W,S,...] -> [H,W,...]."""
    oh = _expand(_oh(slot, arr.shape[2]), arr)
    if arr.dtype == jnp.bool_:
        return jnp.any(oh & arr, axis=2)
    return jnp.sum(jnp.where(oh, arr, 0), axis=2).astype(arr.dtype)


def put(arr, slot, val, mask):
    """arr[II, JJ, slot] = val where mask.  val: [H,W,...] or scalar."""
    oh = _expand(_oh(slot, arr.shape[2], mask), arr)
    val = jnp.asarray(val, arr.dtype)
    if val.ndim >= 2 and val.shape[:2] == arr.shape[:2]:
        val = jnp.expand_dims(val, 2)
    return jnp.where(oh, val, arr)


# --------------------------------------------------------------------------
# EXEC-A: staging — the active action emits its next message (1 per cycle)
# --------------------------------------------------------------------------

def staging_stage(cfg: EngineConfig, app: DiffusionApp, st: MachineState,
                  rows, cols):
    H, W, S, E = cfg.height, cfg.width, cfg.slots, cfg.edge_cap
    QB, WM = cfg.qbatch, cfg.msg_words
    # app-like message builder: classic scalar payload at qbatch == 1
    # (bit-exact with the pre-mq trace), the full [..., QB] query-vector
    # payload otherwise (DESIGN §10); wm pads non-app records to width
    if QB == 1:
        qmsg = lambda op_, dst_, val: make_msg(op_, dst_, f2i(val))
        wm = lambda m_: m_
    else:
        qmsg = lambda op_, dst_, val: make_qmsg(op_, dst_, f2i(val))
        wm = lambda m_: pad_msg(m_, WM)
    active = st.cvalid & (st.cphase >= 1) & (st.cphase <= st.cT)

    op = st.cmsg[..., 0]
    dst = st.cmsg[..., 1]
    slot = dst % S
    k = st.cphase - 1  # emission index
    cellid = rows * W + cols

    is_app = op == OP_APP
    if cfg.faults is not None:
        # an active OP_REPAIR emits exactly like OP_APP (edge diffusion,
        # sibling broadcast, ghost forward) — only the ghost forward
        # keeps the OP_REPAIR opcode so the *whole* chain re-diffuses
        # its edge shard even where the relax changed nothing (§9)
        is_rp = op == OP_REPAIR
        is_app = is_app | is_rp
    is_sf = op == OP_SET_FUTURE
    is_rf = op == OP_RHIZOME_FWD
    is_appl = is_app | is_rf       # app-like: edge diffusion + ghost forward

    # ---- emission for OP_APP / OP_RHIZOME_FWD: (rf only) deferred-insert
    #      drains, per-edge diffusion, (app only) sibling-rhizome
    #      broadcasts, then ghost forward ----
    kd = k - st.cdrain             # emission index past the drains (rf)
    ne = sel(st.nedges, slot)
    ek = jnp.clip(kd, 0, E - 1)
    ohSE = (_oh(slot, S)[..., None] & _oh(ek, E)[..., None, :])  # [H,W,S,E]
    e_dst = jnp.sum(jnp.where(ohSE, st.edst, 0), axis=(2, 3))
    e_w = jnp.sum(jnp.where(ohSE, st.ew, 0.0), axis=(2, 3))
    app_edge_msg = qmsg(OP_APP, e_dst, app.edge_value(st.cemit, e_w))
    gs = sel(st.gstate, slot)
    ga = sel(st.gaddr, slot)
    fwd_op = OP_APP if cfg.faults is None else \
        jnp.where(is_rp, OP_REPAIR, OP_APP)
    app_fwd_msg = qmsg(fwd_op, ga, st.cemit)
    # sibling broadcast window [ne, ne + n_bcast) — canonical roots of
    # multi-root vertices only (phase0 accounted for it in cT)
    rss = sel(st.rstate, slot)
    n_bcast = jnp.where(is_app & (slot < cfg.root_slots) & (rss == G_SET),
                        cfg.rhizome_cap - 1, 0)
    v_self = slot * cfg.n_cells + cellid           # vid owning a root slot
    sib = jnp.clip(kd - ne + 1, 1, cfg.rhizome_cap - 1 if cfg.rhizome_cap > 1
                   else 1)
    bc_msg = qmsg(OP_RHIZOME_FWD, rhizome_addr(cfg, v_self, sib), st.cemit)
    is_bcast = is_app & (kd >= ne) & (kd < ne + n_bcast)
    appl_is_fwd = is_appl & (kd >= ne + n_bcast) & (k >= st.cdrain)

    # ---- emission for OP_SET_FUTURE: retarget head of the future queue,
    #      then (last) the coalesced deferred app-forward, if any ----
    fqn_cur = sel(st.fq_n, slot)
    fqh_cur = sel(st.fq_head, slot)
    fq_slot = jnp.sum(jnp.where(_expand(_oh(slot, S), st.fq), st.fq, 0),
                      axis=2)                                # [H,W,FQ,3]
    fq_e = rings.ring_peek(fq_slot, fqh_cur)                 # [H,W,3]
    sf_is_ins = fq_e[..., 0] == OP_INSERT_EDGE
    if QB == 1:
        sf_fq_app = make_msg(OP_APP, ga, fq_e[..., 1])
    else:
        # deferred-queue entries carry one value word; the remaining
        # query slots ride as the app's neutral element (no-op relaxes)
        qn = jnp.broadcast_to(
            f2i(neutral_vec(app.init_val))[1:], (H, W, QB - 1))
        sf_fq_app = make_qmsg(OP_APP, ga,
                              jnp.concatenate([fq_e[..., 1:2], qn], axis=-1))
    sf_fq_msg = jnp.where(
        sf_is_ins[..., None],
        wm(make_msg(OP_INSERT_EDGE, ga, fq_e[..., 1], fq_e[..., 2])),
        sf_fq_app)
    sf_from_fq = is_sf & (fqn_cur > 0)
    sf_from_fwd = is_sf & (fqn_cur == 0)   # the coalesced forward
    fwd_here = sel(st.fwd_val, slot)
    sf_msg = jnp.where(sf_from_fq[..., None], sf_fq_msg,
                       qmsg(OP_APP, ga, fwd_here))

    # ---- rf activation drain: re-inject a deferred insert at this (now
    #      active) rhizome root — it is local by construction ----
    rf_drain = is_rf & (k < st.cdrain)
    drain_msg = wm(make_msg(OP_INSERT_EDGE, dst, fq_e[..., 1], fq_e[..., 2]))

    appl_msg = jnp.where(rf_drain[..., None], drain_msg,
                         jnp.where(appl_is_fwd[..., None], app_fwd_msg,
                                   jnp.where(is_bcast[..., None], bc_msg,
                                             app_edge_msg)))
    emis = jnp.where(is_appl[..., None], appl_msg,
                     jnp.where(is_sf[..., None], sf_msg, st.cout))
    if cfg.faults is not None:
        # staging is the single chokepoint every compute-emitted message
        # passes through (phase-0's cout rides the is_sf/is_appl=False
        # branch above), so sealing here + at the IO injector covers the
        # whole network (§9); park/rotate/hop paths copy words verbatim
        emis = seal_msg(emis)

    # ---- app ghost-forward onto a *pending* future: coalesce into the
    #      per-slot monotone forward register (never stalls — the future
    #      LCO merges dependent continuations, DESIGN §4.4) ----
    to_reg = active & appl_is_fwd & (gs == G_PENDING)
    ohreg = _oh(slot, S, to_reg)
    # the register coalesces with the app's own meet (min for the bundled
    # min-monotone apps — the pre-mq jnp.minimum — max for widest-path)
    if QB == 1:
        fwd_val = jnp.where(ohreg,
                            app.fwd_merge(st.fwd_val, st.cemit[..., None]),
                            st.fwd_val)
    else:
        fwd_val = jnp.where(ohreg[..., None],
                            app.fwd_merge(st.fwd_val,
                                          st.cemit[..., None, :]),
                            st.fwd_val)
    fwd_pending = st.fwd_pending | ohreg

    tb = yx_target_buffer(cfg, emis[..., 1] // S, rows, cols)

    # ---- try to push (network or local queue) ----
    push_active = active & ~to_reg
    # local delivery uses the reserved slots -> never self-deadlocks;
    # channel pushes enter the emission's virtual lane (escape lane 0
    # for protocol messages, destination-hashed data lane otherwise)
    aq, aq_n, ch, ch_n, ok_push = deliver(
        cfg, st.aq, st.aq_n, st.aq_head, st.ch, st.ch_n, st.ch_head,
        emis, tb, msg_lane(cfg, emis[..., 0], emis[..., 1]), push_active,
        rings.ring_free(st.aq_n, cfg.queue_cap))
    ok_total = to_reg | ok_push  # register writes always succeed
    parked = jnp.zeros_like(ok_push)
    pk, pk_n = st.pk, st.pk_n
    if cfg.lanes > 1:
        # transit parking (DESIGN §7): a remote emission whose channel
        # lane is full is stored into the cell's park buffer instead of
        # wedging the pipeline — the cell keeps consuming (the
        # consumption guarantee that, with the escape lane, makes the
        # §4.2 protocol live).  The park buffer is deliberately a
        # SEPARATE ring: in-transit messages must never occupy action-
        # queue space, or they would hold the queue above the admission
        # thresholds and starve the very deliveries that drain them.
        # routing.park_stage re-injects parked messages each cycle.  If
        # the park buffer is full the action simply stays active (the
        # pre-lane wormhole stall — lossless fallback).
        parked = (push_active & ~ok_push & (tb != TB_AQ_SELF)
                  & rings.ring_free(pk_n, cfg.park_capacity))
        pk, pk_n = rings.ring_push(pk, pk_n, st.pk_head, emis, parked)
        ok_total = ok_total | parked

    # ---- SET_FUTURE / rf-drain bookkeeping on successful stages ----
    fq_pop = ok_total & (sf_from_fq | rf_drain)
    n2, h2 = rings.ring_pop(fqn_cur, fqh_cur, cfg.futq_cap, fq_pop)
    fq_n = put(st.fq_n, slot, n2, fq_pop)
    fq_head = put(st.fq_head, slot, h2, fq_pop)
    sf_clear = ok_total & sf_from_fwd
    fwd_val = put(fwd_val, slot, neutral_vec(app.fwd_neutral), sf_clear)
    fwd_pending = fwd_pending & ~_oh(slot, S, sf_clear)

    # ---- advance / retire ----
    new_phase = st.cphase + ok_total.astype(jnp.int32)
    done = active & ok_total & (new_phase > st.cT)
    cvalid = st.cvalid & ~done
    stall = active & ~ok_total

    st = st._replace(
        aq=aq, aq_n=aq_n, ch=ch, ch_n=ch_n, pk=pk, pk_n=pk_n,
        fq_n=fq_n, fq_head=fq_head,
        fwd_val=fwd_val, fwd_pending=fwd_pending,
        cphase=new_phase, cvalid=cvalid,
        stat_exec=st.stat_exec + jnp.sum(done.astype(jnp.int32)),
        stat_stall=st.stat_stall
        + jnp.sum(stall.astype(jnp.int32))
        + jnp.sum(parked.astype(jnp.int32)))
    if cfg.telemetry:
        i32 = lambda m: m.astype(jnp.int32)
        tm = st.tm_cell
        tm = tm.at[..., TM_STAGE].add(i32(active & ok_total))
        tm = tm.at[..., TM_STALL].add(i32(stall))
        tm = tm.at[..., TM_PARK].add(i32(parked))
        tm = tm.at[..., TM_BCAST].add(i32(push_active & ok_total & is_bcast))
        st = st._replace(tm_cell=tm)
    return st, active


# --------------------------------------------------------------------------
# EXEC-B: pop + phase 0 (the action's computing instruction)
# --------------------------------------------------------------------------

def phase0_stage(cfg: EngineConfig, app: DiffusionApp, st: MachineState,
                 rows, cols, busy_at_start):
    H, W, S, E = cfg.height, cfg.width, cfg.slots, cfg.edge_cap
    FQ, Q = cfg.futq_cap, cfg.queue_cap
    QB, WM = cfg.qbatch, cfg.msg_words
    wm = (lambda m_: m_) if QB == 1 else (lambda m_: pad_msg(m_, WM))
    cellid = rows * W + cols

    idle = ~busy_at_start
    has = idle & (st.aq_n > 0)
    m = rings.ring_peek(st.aq, st.aq_head)  # [H,W,MSG]
    op = jnp.where(has, m[..., 0], 0)
    if cfg.faults is not None:
        # seal validation (DESIGN §9): an app/repair flit whose XOR seal
        # no longer matches was corrupted in transit — discard it as a
        # counted no-op rather than relax with a poisoned value (a
        # corrupted-low level could never be un-relaxed from a monotone
        # fixpoint).  Protocol traffic is never corrupted by a FaultPlan
        # so restricting the check keeps legacy in-state messages valid.
        from repro.resilience.faults import FLT_CORRUPT, is_droppable
        bad = has & is_droppable(op) & (msg_seal(m) != m[..., 4])
        op = jnp.where(bad, 0, op)
    dst, a0, a1 = m[..., 1], m[..., 2], m[..., 3]
    slot = dst % S

    vals_s = sel(st.vals, slot)             # [H,W,VN]
    ne = sel(st.nedges, slot)
    gs = sel(st.gstate, slot)
    fqn = sel(st.fq_n, slot)
    rs = sel(st.rstate, slot)
    on_s = sel(st.rhz_on, slot)

    is_ins = op == OP_INSERT_EDGE
    is_app = op == OP_APP
    is_alc = op == OP_ALLOC
    is_sf = op == OP_SET_FUTURE
    is_rf = op == OP_RHIZOME_FWD
    is_lr = op == OP_LINK_RHIZOME
    # recovery-path relax (DESIGN §9): like OP_APP but *forces* the
    # re-diffusion emissions even when the relax did not change the
    # value — rebuilding downstream state lost to dropped flits
    is_rp = (op == OP_REPAIR) if cfg.faults is not None else None

    # secondary rhizome slots are statically reserved but start inactive;
    # an insert reaching one before its link-ack must defer (DESIGN §4.5)
    in_sec = (slot >= cfg.root_slots) & (slot < cfg.primary_slots)
    inactive = in_sec & ~on_s

    # ---------------- INSERT-EDGE paths (Listing 6) ----------------
    room = ne < E
    p_room = is_ins & ~inactive & room
    p_fwd = is_ins & ~inactive & ~room & (gs == G_SET)
    p_defer = is_ins & ~inactive & ~room & (gs == G_PENDING)
    p_null = is_ins & ~inactive & ~room & (gs == G_NULL)
    # rhizome growth: first insert at an inactive root requests the link,
    # later ones just defer on the same future queue (Fig. 4 machinery)
    p_rlink = is_ins & inactive & (rs == G_NULL)
    p_rdef = is_ins & inactive & (rs == G_PENDING)

    # the only infeasible phase-0: deferred insert with a full future
    # queue.  The head is ROTATED to the queue tail (costs this cell's
    # cycle) — the paper's runtime "schedules other tasks", so a blocked
    # action never wedges the FIFO in front of the set-future it waits on.
    feasible = ~((p_defer | p_rlink | p_rdef) & (fqn >= FQ))
    pop = has & feasible
    rotate = has & ~feasible
    p_room &= pop; p_fwd &= pop; p_defer &= pop; p_null &= pop
    p_rlink &= pop; p_rdef &= pop
    is_app &= pop; is_alc &= pop; is_sf &= pop; is_rf &= pop; is_lr &= pop
    if is_rp is not None:
        is_rp &= pop

    # -- room: insert the edge into this RPVO node
    eidx = jnp.minimum(ne, E - 1)
    ohSE = (_oh(slot, S, p_room)[..., None]
            & _oh(eidx, E)[..., None, :])                    # [H,W,S,E]
    edst = jnp.where(ohSE, a0[..., None, None], st.edst)
    ew = jnp.where(ohSE, i2f(a1)[..., None, None], st.ew)
    nedges = st.nedges + _oh(slot, S, p_room).astype(jnp.int32)
    prop = app.propagate_on_insert(vals_s)
    ins_T = (p_room & prop).astype(jnp.int32)
    if QB == 1:
        ins_out = make_msg(OP_APP, a0,
                           f2i(app.edge_value(vals_s[..., 0], i2f(a1))))
    else:
        # the insert-propagate relax carries the whole query vector: one
        # wave serves every tenant (DESIGN §10)
        ins_out = make_qmsg(OP_APP, a0,
                            f2i(app.edge_value(vals_s, i2f(a1))))

    # -- fwd: recursively propagate the insert to the ghost (Listing 6 l.29)
    ga_cur = sel(st.gaddr, slot)
    fwd_out = wm(make_msg(OP_INSERT_EDGE, ga_cur, a0, a1))

    # -- defer: enqueue the insert on the pending future (Fig. 4 step 3)
    # (rhizome-pending slots reuse the same queue: Fig. 4 step 3 again)
    push_mask = p_defer | p_null | p_rlink | p_rdef
    fqh = sel(st.fq_head, slot)
    tailq = (fqh + fqn) % FQ
    ohq = (_oh(slot, S, push_mask)[..., None]
           & _oh(tailq, FQ)[..., None, :])                   # [H,W,S,FQ]
    entry = jnp.stack([jnp.full((H, W), OP_INSERT_EDGE, jnp.int32), a0, a1],
                      axis=-1)                               # [H,W,3]
    fq = jnp.where(ohq[..., None], entry[..., None, None, :], st.fq)
    fq_n = st.fq_n + _oh(slot, S, push_mask).astype(jnp.int32)

    # -- null: future -> pending, send allocate with continuation (Fig. 3)
    gstate = put(st.gstate, slot, G_PENDING, p_null)
    tgt_cell = choose_alloc_cell(cfg, rows, cols, st.arot)
    arot = st.arot + p_null.astype(jnp.int32)
    null_out = make_msg(OP_ALLOC, tgt_cell * S, dst, f2i(vals_s[..., 0]))
    if QB > 1:
        # OP_ALLOC carries the requester's full value vector: word 3 is
        # slot 0 (as ever), the extension words are slots 1.. (§10)
        null_out = jnp.concatenate([null_out, f2i(vals_s[..., 1:])], axis=-1)

    # -- rlink: mark pending, request activation at the canonical root
    rstate = put(st.rstate, slot, G_PENDING, p_rlink)
    owner = rhizome_owner_vid(cfg, cellid, slot)
    owner_root = (owner % cfg.n_cells) * S + owner // cfg.n_cells
    rlink_out = wm(make_msg(OP_LINK_RHIZOME, owner_root, cellid * S + slot))

    # ---------------- APP / RHIZOME-FWD relax (Listing 5) ----------------
    relaxing = is_app | is_rf
    app_like = is_app
    if is_rp is not None:
        relaxing = relaxing | is_rp
        app_like = is_app | is_rp
    if QB == 1:
        new_vals, changed = app.relax(vals_s, i2f(a0))
        changed = changed & relaxing
    else:
        # vector relax over the query axis (DESIGN §10): the incoming
        # payload spans all query slots; the qsel bitmask (word 3, 0 =
        # all) masks de-selected slots to their app's neutral element so
        # an admission re-seed relaxes exactly one tenant
        inc = i2f(msg_qvals(m, QB))                       # [H,W,QB]
        inc = jnp.where(qsel_mask(a1, QB), inc, neutral_vec(app.init_val))
        new_vals, changed_q = app.relax(vals_s, inc)
        changed_q = changed_q & relaxing[..., None]       # [H,W,QB]
        changed = jnp.any(changed_q, axis=-1)
    vals = put(st.vals, slot, new_vals, relaxing)
    # a changed relax at a canonical root of a multi-root vertex also
    # broadcasts to the R-1 sibling rhizomes — in parallel, replacing the
    # serial forward walk of the chain design (DESIGN §4.5).  The root
    # learns it is multi-root when it handles the first OP_LINK_RHIZOME.
    n_bcast = jnp.where(app_like & (slot < cfg.root_slots) & (rs == G_SET),
                        cfg.rhizome_cap - 1, 0)
    forced = changed if is_rp is None else changed | is_rp
    app_T = jnp.where(forced,
                      ne + n_bcast + (gs != G_NULL).astype(jnp.int32), 0)
    cemit_new = new_vals[..., 0] if QB == 1 else new_vals

    # -- rhizome-fwd extras: activate a pending/inactive sibling root and
    #    drain its deferred inserts back onto the local action queue.  The
    #    gstate gate keeps ghost-protocol deferrals (G_PENDING) parked for
    #    their set-future instead of bouncing them through the queue.
    rf_act = is_rf & in_sec & ~on_s
    rhz_on = jnp.where(_oh(slot, S, rf_act), True, st.rhz_on)
    rstate = put(rstate, slot, G_SET, rf_act)
    # the ne == 0 gate makes the §4.2 local-emission bound locally
    # provable: a draining rf emits <= futq_cap (<= aq_reserve) and a
    # diffusing rf emits <= edge_cap + 1, never both.  (Protocol-wise a
    # slot with fq entries is either ghost-pending or pre-activation with
    # zero edges, so the gate never strands an entry.)
    drain_n = jnp.where(is_rf & (gs != G_PENDING) & (ne == 0), fqn, 0)
    rf_T = drain_n + jnp.where(is_rf & changed,
                               ne + (gs != G_NULL).astype(jnp.int32), 0)
    app_T = jnp.where(is_rf, 0, app_T)

    # ---------------- LINK-RHIZOME (canonical-root handler) ----------
    # remember the vertex is multi-root; ack with the current value (the
    # ack is itself an OP_RHIZOME_FWD, so it also syncs the new sibling)
    rstate = put(rstate, slot, G_SET, is_lr)
    if QB == 1:
        lr_out = make_msg(OP_RHIZOME_FWD, a0, f2i(vals_s[..., 0]))
    else:
        lr_out = make_qmsg(OP_RHIZOME_FWD, a0, f2i(vals_s))

    # ---------------- ALLOC (system action) ----------------
    alc_room = is_alc & (st.nfree < S)
    alc_full = is_alc & ~(st.nfree < S)
    g_new = st.nfree
    if QB == 1:
        gseed = (jnp.full((H, W, cfg.n_vals), jnp.float32(app.init_val))
                 .at[..., 0].set(i2f(a1)))
    else:
        # the allocation request carried the requester's whole value
        # vector (word 3 + extension words), so the ghost starts synced
        gseed = i2f(jnp.concatenate([a1[..., None], m[..., MSG_WORDS:]],
                                    axis=-1))
    vals = put(vals, g_new, gseed, alc_room)
    nedges = put(nedges, g_new, 0, alc_room)
    gaddr0 = put(st.gaddr, g_new, -1, alc_room)
    gstate = put(gstate, g_new, G_NULL, alc_room)
    fq_n = put(fq_n, g_new, 0, alc_room)
    fq_head = put(st.fq_head, g_new, 0, alc_room)
    fwd_val = put(st.fwd_val, g_new, neutral_vec(app.fwd_neutral), alc_room)
    fwd_pending = st.fwd_pending & ~_oh(g_new, S, alc_room)
    new_addr = cellid * S + st.nfree
    nfree = st.nfree + alc_room.astype(jnp.int32)
    alc_ok_out = wm(make_msg(OP_SET_FUTURE, a0, new_addr))
    nxt_cell = (cellid + 1) % cfg.n_cells
    alc_fwd_out = make_msg(OP_ALLOC, nxt_cell * S, a0, a1)
    if QB > 1:
        alc_fwd_out = jnp.concatenate([alc_fwd_out, m[..., MSG_WORDS:]],
                                      axis=-1)

    # ---------------- SET-FUTURE (continuation return, Fig. 3/4) ----------
    gaddr = put(gaddr0, slot, a0, is_sf)
    gstate = put(gstate, slot, G_SET, is_sf)
    sf_T = jnp.where(is_sf,
                     fqn + sel(st.fwd_pending, slot).astype(jnp.int32), 0)

    # ---------------- combine: T, cout, registers, queue pop --------------
    T = (ins_T
         + jnp.where(p_fwd | p_null | p_rlink | alc_room | alc_full | is_lr,
                     1, 0)
         + app_T + sf_T + rf_T)
    cout = jnp.where(p_room[..., None], ins_out,
            jnp.where(p_fwd[..., None], fwd_out,
             jnp.where(p_null[..., None], null_out,
              jnp.where(p_rlink[..., None], rlink_out,
               jnp.where(is_lr[..., None], lr_out,
                jnp.where(alc_room[..., None], alc_ok_out,
                 jnp.where(alc_full[..., None], alc_fwd_out, st.cout)))))))

    # pop (feasible) or rotate-to-tail (infeasible): head always advances
    move = pop | rotate
    tail = (st.aq_head + st.aq_n) % Q
    ohT = _oh(tail, Q, rotate)                                # [H,W,Q]
    aq = jnp.where(ohT[..., None], m[..., None, :], st.aq)
    aq_n2 = st.aq_n - pop.astype(jnp.int32)
    aq_h2 = (st.aq_head + move.astype(jnp.int32)) % Q
    done0 = pop & (T == 0)   # single-cycle action
    cvalid = st.cvalid | (pop & (T > 0))
    cmsg = jnp.where(pop[..., None], m, st.cmsg)
    cphase = jnp.where(pop, 1, st.cphase)
    cT = jnp.where(pop, T, st.cT)
    cemit = jnp.where(relaxing if QB == 1 else relaxing[..., None],
                      cemit_new, st.cemit)
    cdrain = jnp.where(pop, jnp.where(is_rf, drain_n, 0), st.cdrain)

    st = st._replace(
        vals=vals, nedges=nedges, edst=edst, ew=ew, gaddr=gaddr,
        gstate=gstate, rhz_on=rhz_on, rstate=rstate, nfree=nfree,
        fq=fq, fq_n=fq_n, fq_head=fq_head,
        fwd_val=fwd_val, fwd_pending=fwd_pending,
        aq=aq, aq_n=aq_n2, aq_head=aq_h2, arot=arot,
        cmsg=cmsg, cvalid=cvalid, cphase=cphase, cT=cT, cemit=cemit,
        cout=cout, cdrain=cdrain,
        stat_exec=st.stat_exec + jnp.sum(done0.astype(jnp.int32)),
        stat_allocs=st.stat_allocs + jnp.sum(alc_room.astype(jnp.int32)),
        stat_stall=st.stat_stall + jnp.sum(rotate.astype(jnp.int32)))
    if QB > 1:
        # per-query activity counters (repro.mq, DESIGN §10): a query
        # slot that relaxed nowhere this cycle is one cycle closer to
        # its own quiescence — the session layer diffs qchg across
        # increments and reads qlast as the slot's settle cycle
        dq = jnp.sum(changed_q.astype(jnp.int32), axis=(0, 1))
        st = st._replace(qchg=st.qchg + dq,
                         qlast=jnp.where(dq > 0, st.cycle, st.qlast))
    if cfg.faults is not None:
        st = st._replace(flt=st.flt.at[FLT_CORRUPT].add(
            jnp.sum(bad.astype(jnp.int32))))
    if cfg.telemetry:
        tm = st.tm_cell
        tm = tm.at[..., TM_EXEC].add(pop.astype(jnp.int32))
        tm = tm.at[..., TM_ALLOC].add(alc_room.astype(jnp.int32))
        tm = tm.at[..., TM_STALL].add(rotate.astype(jnp.int32))
        st = st._replace(tm_cell=tm)
    return st, pop
