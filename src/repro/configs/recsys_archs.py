"""dlrm-rm2 [arXiv:1906.00091] — the assigned recsys architecture."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchBundle, recsys_shapes
from repro.models.dlrm import DLRMConfig

DLRM_RM2 = DLRMConfig(
    name="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
    bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1), interaction="dot",
    lookups_per_field=4)


def _smoke(cfg: DLRMConfig) -> DLRMConfig:
    return dataclasses.replace(
        cfg, n_sparse=4, embed_dim=8, bot_mlp=(16, 8), top_mlp=(16, 8, 1),
        vocab_sizes=(64, 32, 16, 8), lookups_per_field=2)


def bundles():
    return [ArchBundle(
        "dlrm-rm2", "recsys", DLRM_RM2, recsys_shapes(),
        lambda: _smoke(DLRM_RM2),
        notes="embedding lookup = 'work to data' (DESIGN §5); "
              "tables row-sharded over the model axis")]
