"""--arch gatedgcn (exact published config; see gnn_archs.py)."""
from repro.configs.gnn_archs import GATEDGCN as CONFIG
from repro.configs.registry import get

BUNDLE = get("gatedgcn")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
