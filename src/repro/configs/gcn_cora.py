"""--arch gcn-cora (exact published config; see gnn_archs.py)."""
from repro.configs.gnn_archs import GCN_CORA as CONFIG
from repro.configs.registry import get

BUNDLE = get("gcn-cora")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
