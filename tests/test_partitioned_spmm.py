"""Owner-partitioned SpMM == plain segment_sum (multi-device subprocess)."""
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.graph.partition import partition_edges, spmm_partitioned
from repro.graph.segment_ops import spmm

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rng = np.random.default_rng(0)
N, E, D = 64, 500, 16
src = rng.integers(0, N, E).astype(np.int32)
dst = rng.integers(0, N, E).astype(np.int32)
x = rng.standard_normal((N, D), dtype=np.float32)
coeff = rng.standard_normal(E).astype(np.float32)

part = partition_edges(np.stack([src, dst]), N, 8)
# pad coeff to the partitioned layout (recompute per-edge coeff by lookup)
key = {(int(s), int(d)): float(c) for s, d, c in zip(src, dst, coeff)}
# duplicate edges share coeff; rebuild by matching original positions
cpart = np.zeros(part.shape[1], np.float32)
used = {}
orig = {}
for i, (s, d) in enumerate(zip(src, dst)):
    orig.setdefault((int(s), int(d)), []).append(coeff[i])
for j in range(part.shape[1]):
    s, d = int(part[0, j]), int(part[1, j])
    if d >= N:
        continue
    lst = orig[(s, d)]
    cpart[j] = lst[used.get((s, d), 0) % len(lst)]
    used[(s, d)] = used.get((s, d), 0) + 1

with jax.set_mesh(mesh):
    got = spmm_partitioned(jnp.asarray(x), jnp.asarray(part), N,
                           jnp.asarray(cpart), mesh)
want = spmm(jnp.asarray(x.astype(np.float32)), jnp.stack([src, dst]), N,
            jnp.asarray(coeff))
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=2e-2, atol=2e-2)  # bf16 gather
print("PART_SPMM_OK")
"""


def test_partitioned_spmm_matches():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "PART_SPMM_OK" in r.stdout, r.stdout + r.stderr


def test_partition_edges_layout():
    import numpy as np
    from repro.graph.partition import partition_edges, PAD_DST
    rng = np.random.default_rng(1)
    src = rng.integers(0, 40, 200).astype(np.int32)
    dst = rng.integers(0, 40, 200).astype(np.int32)
    part = partition_edges(np.stack([src, dst]), 40, 4)
    assert part.shape[1] % 4 == 0
    emax = part.shape[1] // 4
    n_loc = 10
    for s in range(4):
        blk = part[1, s * emax:(s + 1) * emax]
        real = blk[blk != PAD_DST]
        assert ((real // n_loc) == s).all()
    # every edge present exactly once
    real_cols = part[:, part[1] != PAD_DST]
    assert real_cols.shape[1] == 200
