"""Engine-backend throughput benchmark -> ``results/bench_engine.json``.

Starts the perf trajectory for the cycle engine itself (DESIGN §6):

  * per-backend (jnp lax chunk runners vs the fused Pallas cycle
    megakernel, interpret mode off-TPU) cycles/sec and end-to-end
    increment wall-clock on a BFS stream, with a bit-exactness check
    between the two backends;
  * a livelock-detector smoke on both backends (undersized buffers must
    raise, DESIGN §4.2) — CI fails on either regression;
  * the ``--only increments`` ci-scale wall-clock trajectory: the
    pre-PR chunked host driver baseline vs the sync-free
    ``collect_traces=False`` fast path (recorded via ``--record-increments``,
    not in the CI smoke job — it is minutes of CPU).

Scales are engine-local (like SKEW_SCALES): the megakernel's VMEM
residency claim is about the chip state, so a small grid measures the
same effect in seconds.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core import EngineConfig, StreamingEngine
from repro.core.reference import bfs_levels
from repro.graph.streams import StreamSpec, make_stream

OUT = "results/bench_engine.json"

ENGINE_SCALES = {
    "ci": dict(height=8, width=8, n_vertices=256, n_edges=2048, chunk=64),
    "mid": dict(height=16, width=16, n_vertices=2048, n_edges=16_384,
                chunk=128),
}


def _cfg(p: dict, backend: str, **kw) -> EngineConfig:
    base = dict(height=p["height"], width=p["width"],
                n_vertices=p["n_vertices"], edge_cap=8,
                ghost_slots=max(64, 4 * p["n_edges"]
                                // (8 * p["height"] * p["width"])),
                queue_cap=64, chan_cap=16, futq_cap=8,
                io_stream_cap=2 ** 18, chunk=p["chunk"], backend=backend)
    base.update(kw)
    return EngineConfig(**base)


def bench_engine(scale: str = "ci", profile: bool = False) -> dict:
    """Backend throughput + parity + livelock smoke; merges into OUT.

    ``profile=True`` additionally runs both backends with
    ``telemetry=True`` on the same stream (the ``--profile`` flag of
    ``benchmarks.run``): records the telemetry overhead vs the plain
    run, asserts a non-empty frame log, and dumps the Chrome trace and
    congestion heatmap under ``results/profile/`` (DESIGN §8).
    """
    p = ENGINE_SCALES.get(scale, ENGINE_SCALES["mid"])  # paper -> mid grid
    spec = StreamSpec(n_vertices=p["n_vertices"], n_edges=p["n_edges"],
                      increments=2, sampling="edge", seed=3)
    incs = make_stream(spec)
    want = bfs_levels(p["n_vertices"], np.concatenate(incs), 0)
    n_cells = p["height"] * p["width"]

    rec: dict = dict(scale=scale, grid=f'{p["height"]}x{p["width"]}',
                     n_vertices=p["n_vertices"], n_edges=p["n_edges"],
                     chunk=p["chunk"], backends={})
    finals = {}
    for backend in ("jnp", "pallas"):
        eng = StreamingEngine(_cfg(p, backend), "bfs")
        eng.seed(0, 0.0)
        eng.run_increment(incs[0], max_cycles=2_000_000)  # warm the jit
        t0 = time.time()
        r = eng.run_increment(incs[1], max_cycles=2_000_000)
        dt = time.time() - t0
        np.testing.assert_array_equal(eng.values(p["n_vertices"]), want)
        finals[backend] = eng.state
        rec["backends"][backend] = dict(
            cycles=r.cycles, wall_s=round(dt, 3),
            cyc_per_s=round(r.cycles / dt, 1),
            cell_cycles_per_s=round(r.cycles / dt * n_cells, 0),
            execs=r.execs, hops=r.hops, total_cycles=eng.total_cycles)
        if profile:
            rec["backends"][backend]["profile"] = _profile_backend(
                p, backend, incs, dt, r)

    # bit-exactness across backends (the CI parity gate)
    for name, a, b in zip(finals["jnp"]._fields, finals["jnp"],
                          finals["pallas"]):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state leaf '{name}' diverged between backends")
    rec["parity"] = "bit-exact"

    # livelock detector must fire identically on both backends
    rec["livelock_detector"] = {}
    bad = make_stream(StreamSpec(n_vertices=64, n_edges=400, increments=1,
                                 seed=21))[0]
    for backend in ("jnp", "pallas"):
        cfg = EngineConfig(height=8, width=8, n_vertices=64, edge_cap=2,
                           ghost_slots=48, queue_cap=8, chan_cap=2,
                           futq_cap=2, io_stream_cap=2048, chunk=64,
                           backend=backend)
        eng = StreamingEngine(cfg, "bfs")
        eng.seed(0, 0.0)
        try:
            eng.run_increment(bad, max_cycles=200_000)
            raise AssertionError(
                f"livelock NOT detected on backend={backend}")
        except RuntimeError as e:
            assert "livelock" in str(e), e
            rec["livelock_detector"][backend] = "fires"
    if profile:
        # recovery-path cost on the happy path: checkpoint-cadence sweep
        # + faults-off vs faults-on wall-clock deltas (DESIGN §9)
        from benchmarks.resilience_smoke import profile_resilience
        rec["resilience_profile"] = profile_resilience(scale)
    _merge(rec, key=f"engine_{scale}")
    return rec


def _profile_backend(p: dict, backend: str, incs, plain_wall_s: float,
                     plain_result) -> dict:
    """Telemetry-on rerun of the timed increment: overhead, frame-total
    reconciliation against the plain run, and the exporter dumps."""
    from repro.obs import engine_rates, write_chrome_trace, write_heatmap

    eng = StreamingEngine(_cfg(p, backend, telemetry=True), "bfs")
    eng.seed(0, 0.0)
    eng.run_increment(incs[0], max_cycles=2_000_000)  # warm the jit
    t0 = time.time()
    r = eng.run_increment(incs[1], max_cycles=2_000_000)
    dt = time.time() - t0
    assert r.frames is not None and len(r.frames) > 0, \
        f"telemetry produced no frames on backend={backend}"
    # the final frame must reconcile exactly with the scalar counters of
    # the bit-exact plain run (DESIGN §8)
    t = r.frames.totals()
    assert (t["hops"], t["execs"]) == (plain_result.hops,
                                       plain_result.execs), \
        (f"frame totals diverged from counters on backend={backend}: "
         f"{t} vs hops={plain_result.hops} execs={plain_result.execs}")
    trace = write_chrome_trace(f"results/profile/trace_{backend}.json",
                               eng.cfg, r.frames)
    heat = write_heatmap(f"results/profile/heatmap_{backend}.json",
                         eng.cfg, r.frames)
    return dict(
        wall_s=round(dt, 3),
        overhead_pct=round(100 * (dt - plain_wall_s) / plain_wall_s, 1),
        frames=len(r.frames), dropped=r.frames.dropped,
        rates={k: round(v, 3) if isinstance(v, float) else v
               for k, v in engine_rates(r.frames).items()},
        trace=trace, heatmap=heat)


def record_increments_wallclock(scale: str = "ci") -> dict:
    """End-to-end ``--only increments`` wall-clock with the sync-free
    fast path, stored next to the recorded pre-PR baseline (minutes of
    CPU — run locally, not in the CI smoke job)."""
    from benchmarks import paper_experiments as pe
    rec = {}
    for sampling in ("edge", "snowball"):
        _, wall = pe.bench_cycles_per_increment(scale, sampling)
        rec[f"{sampling}_wall_s"] = round(wall, 1)
    data = _merge({f"fast_path_{scale}": rec}, key="increments_wallclock")
    base = data.get("increments_wallclock", {}).get(f"pre_pr_baseline_{scale}")
    if base:
        rec["speedup_vs_pre_pr"] = {
            k: round(base[k] / rec[k], 2) for k in rec if k in base}
        _merge({f"fast_path_{scale}": rec}, key="increments_wallclock")
    return rec


def _merge(rec: dict, key: str) -> dict:
    p = pathlib.Path(OUT)
    p.parent.mkdir(parents=True, exist_ok=True)
    data = json.loads(p.read_text()) if p.exists() else {}
    if key == "increments_wallclock":
        data.setdefault(key, {}).update(rec)
    else:
        data[key] = rec
    p.write_text(json.dumps(data, indent=1))
    return data


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="ci", choices=list(ENGINE_SCALES))
    ap.add_argument("--record-increments", action="store_true")
    args = ap.parse_args()
    print(json.dumps(bench_engine(args.scale), indent=1))
    if args.record_increments:
        print(json.dumps(record_increments_wallclock(args.scale), indent=1))
