"""Pure-jnp oracle for causal GQA attention."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True):
    """q: [B, Tq, H, dh]; k/v: [B, Tk, Kh, dh] -> [B, Tq, H, dh] (f32)."""
    B, Tq, H, dh = q.shape
    Tk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qf = q.astype(jnp.float32) / np.sqrt(dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, G, axis=2)
    vf = jnp.repeat(vf, G, axis=2)
    s = jnp.einsum("bthd,bshd->bhts", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhts,bshd->bthd", p, vf)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B, 1, H, dh]; caches [B, T, Kh, dh]; lengths [B] -> [B,1,H,dh]."""
    B, _, H, dh = q.shape
    T, Kh = k_cache.shape[1], k_cache.shape[2]
    G = H // Kh
    qf = q[:, 0].astype(jnp.float32) / np.sqrt(dh)
    kf = jnp.repeat(k_cache.astype(jnp.float32), G, axis=2)
    vf = jnp.repeat(v_cache.astype(jnp.float32), G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", qf, kf)
    mask = jnp.arange(T)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", p, vf)[:, None]
