"""AdamW + schedules + global-norm clipping (pure JAX, optax-free).

Optimizer state inherits each parameter's sharding (ZeRO-style: with
params 2-D sharded over (data, model), the m/v moments are fully sharded
too — no replicated optimizer memory anywhere).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_adamw(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return AdamWState(step=jnp.int32(0), m=zeros(params), v=zeros(params))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
