"""Pipeline parallelism correctness: run in a subprocess with 8 fake host
devices (XLA device count is locked at first jax init, so the multi-device
test must own its process)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.pipeline import pipelined_apply, split_stages

    mesh = jax.make_mesh((4,), ("pipe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    L, D, n_micro, micro = 8, 16, 6, 4
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1

    def layer(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    def stage_fn(p, x):   # apply this stage's L/S layers sequentially
        def body(x, lp):
            return layer(lp, x), None
        x, _ = jax.lax.scan(body, x, p)
        return x

    xs = jax.random.normal(jax.random.PRNGKey(2), (n_micro, micro, D))
    stages = split_stages(dict(w=w, b=b), 4)
    got = pipelined_apply(stage_fn, stages, xs, mesh, axis="pipe")

    # sequential reference
    def ref_one(x):
        for l in range(L):
            x = layer(dict(w=w[l], b=b[l]), x)
        return x
    want = jax.vmap(ref_one)(xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # bubble math: 6 micro + 4 stages - 1 = 9 ticks
    print("PIPELINE_OK")
""")


def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
