"""Active-message ("action") codec.

A message is a fixed 5-word int32 record::

    word 0  opcode        (OP_*, 0 = empty)
    word 1  dst address   (cell * slots + slot)
    word 2  arg0
    word 3  arg1
    word 4  arg2

Float arguments (application values, e.g. BFS levels) are bit-cast into
int32 words -- the 256-bit AM-CCA flit carries opaque operand words the
same way.

Query batching (repro.mq, DESIGN §10) widens the record to
``5 + (qbatch - 1)`` words.  The first five words keep their classic
meaning — payload slot 0 stays in word 2 (arg0) and the integrity seal
stays in word 4 — while payload slots ``1..qbatch-1`` occupy the
extension words ``5..``.  For the app-like opcodes (``OP_APP``,
``OP_REPAIR``, ``OP_RHIZOME_FWD``) word 3 becomes the **qsel** query-id
bitmask: 0 means "all query slots live" (the common in-fabric case — a
diffusion wave carries every tenant), bit ``q`` set restricts the relax
to slot ``q`` (admission re-seeds inject ``qsel = 1 << q``; masked-out
slots relax against their app's neutral element, a no-op).  ``OP_ALLOC``
keeps its requester-value in word 3 and carries slots ``1..`` in the
extension words so a ghost allocation seeds the whole vector.  With
``qbatch == 1`` the layout is byte-identical to the pre-mq flit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MSG_WORDS = 5

# ---- opcodes ----
OP_NOP = 0
OP_INSERT_EDGE = 1    # args: (edge dst root addr, weight bits, -)
OP_APP = 2            # args: (value bits, -, -)   the application action (e.g. bfs-action)
OP_ALLOC = 3          # args: (requester addr, requester value bits, -)
OP_SET_FUTURE = 4     # args: (new ghost addr, -, -)
OP_RHIZOME_FWD = 5    # args: (value bits, -, -)   sibling-rhizome value sync;
                      # also the link-ack that activates a pending rhizome root
OP_LINK_RHIZOME = 6   # args: (requester rhizome addr, -, -) sent to the
                      # canonical root to request activation of a sibling
OP_REPAIR = 7         # args: (value bits, -, -)   recovery-path relax
                      # (DESIGN §9): relaxes like OP_APP but *forces*
                      # re-diffusion over the slot's local edge shard and
                      # down the ghost chain even when the value did not
                      # change — injected by the engine's repair pass to
                      # rebuild state lost to dropped/corrupted app flits
N_OPS = 8

# ---- directions (mesh links) ----
DIR_N, DIR_S, DIR_W, DIR_E = 0, 1, 2, 3
N_DIRS = 4

# ---- staging target-buffer codes (exec stage) ----
TB_NONE = -1
TB_CHAN_N, TB_CHAN_S, TB_CHAN_W, TB_CHAN_E = 0, 1, 2, 3
TB_AQ_SELF = 4
TB_FUTQ = 5


def f2i(x):
    """Bit-cast float32 -> int32 (payload word)."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.int32)


def i2f(x):
    """Bit-cast int32 -> float32."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.int32), jnp.float32)


def make_msg(op, dst, a0=0, a1=0, a2=0):
    """Build a message; broadcasting over leading dims."""
    parts = jnp.broadcast_arrays(
        jnp.asarray(op, jnp.int32), jnp.asarray(dst, jnp.int32),
        jnp.asarray(a0, jnp.int32), jnp.asarray(a1, jnp.int32),
        jnp.asarray(a2, jnp.int32))
    return jnp.stack(parts, axis=-1)


def msg_op(m):
    return m[..., 0]


def msg_dst(m):
    return m[..., 1]


def msg_arg(m, i):
    return m[..., 2 + i]


def msg_seal(m):
    """Integrity seal of a message: XOR of words 0..3 (word 4 is the
    seal slot — unused as an operand by every opcode).  Set at the two
    network-injection chokepoints (staging emissions, IO inserts) when
    ``cfg.faults`` is active; validated by the execute stage at pop so a
    transit-corrupted flit is discarded as a counted no-op instead of
    poisoning the monotone fixpoint (DESIGN §9)."""
    return m[..., 0] ^ m[..., 1] ^ m[..., 2] ^ m[..., 3]


def seal_msg(m):
    """Return ``m`` with word 4 set to :func:`msg_seal`."""
    return jnp.concatenate(
        [m[..., :4], msg_seal(m)[..., None]], axis=-1)


EMPTY_MSG = (0, 0, 0, 0, 0)


# ---------------- query-batched (vector payload) helpers (DESIGN §10) ----


def msg_words(qbatch: int) -> int:
    """Record width in int32 words for a query-batch of ``qbatch``."""
    return MSG_WORDS + max(0, qbatch - 1)


def pad_msg(m, n_words: int):
    """Right-pad a classic 5-word message with zero extension words.

    Used for the non-app opcodes (insert-edge, set-future, link-rhizome)
    whose extension words are dead payload — every buffer in a
    ``qbatch > 1`` machine is ``msg_words`` wide, so all records must
    share the width.
    """
    if m.shape[-1] == n_words:
        return m
    pad = jnp.zeros(m.shape[:-1] + (n_words - m.shape[-1],), m.dtype)
    return jnp.concatenate([m, pad], axis=-1)


def msg_qvals(m, qbatch: int):
    """The ``[..., qbatch]`` int32 payload vector of an app-like message:
    word 2 is slot 0, the extension words are slots 1..  (bit-cast floats
    — pair with :func:`i2f`)."""
    if qbatch == 1:
        return m[..., 2:3]
    return jnp.concatenate([m[..., 2:3], m[..., MSG_WORDS:]], axis=-1)


def make_qmsg(op, dst, qbits, a1=0):
    """Build an app-like message carrying the full ``[..., Q]`` payload
    vector ``qbits`` (int32 bit-cast values): slot 0 rides word 2, slots
    1.. ride the extension words.  ``a1`` is the qsel bitmask (0 = all
    slots live).  At ``Q == 1`` this is exactly :func:`make_msg`."""
    head = make_msg(op, dst, qbits[..., 0], a1)
    if qbits.shape[-1] == 1:
        return head
    return jnp.concatenate([head, qbits[..., 1:]], axis=-1)


def qsel_mask(a1, qbatch: int):
    """``[..., qbatch]`` bool: which query slots an app-like message
    addresses.  ``a1 == 0`` (the in-fabric default) selects all slots;
    otherwise bit ``q`` of ``a1`` selects slot ``q``."""
    bits = (a1[..., None] >> jnp.arange(qbatch, dtype=jnp.int32)) & 1
    return (a1[..., None] == 0) | (bits == 1)
