"""DLRM (RM2-class): sparse embedding bags -> dot interaction -> MLPs.

JAX has no native EmbeddingBag: lookups are ``jnp.take`` +
``jax.ops.segment_sum`` over per-bag offsets — built here as part of the
system (see also the scalar-prefetch Pallas kernel in
kernels/embedding_bag for the TPU hot path).

Embedding tables are row-sharded over the 'model' mesh axis: a lookup is
routed to the shard that owns the row — the paper's "send work to data"
principle applied to recsys (DESIGN §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_sizes: tuple = ()          # len == n_sparse
    lookups_per_field: int = 4       # multi-hot bag size (RM2-style)
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 256, 1)
    interaction: str = "dot"
    compute_dtype: Any = jnp.float32

    def resolved_vocabs(self) -> tuple:
        if self.vocab_sizes:
            return self.vocab_sizes
        # Criteo-like mix: a few huge tables, many small.  All sizes are
        # multiples of 512 so tables row-shard evenly on any mesh axis.
        base = [33_554_432, 8_388_608, 4_194_304, 1_048_576, 524_288,
                131_072, 65_536, 16_384, 4_096, 1_024]
        return tuple(base[i % len(base)] for i in range(self.n_sparse))

    def n_params(self) -> int:
        emb = sum(self.resolved_vocabs()) * self.embed_dim
        sizes = [self.n_dense, *self.bot_mlp]
        bot = sum(sizes[i] * sizes[i + 1] + sizes[i + 1]
                  for i in range(len(sizes) - 1))
        n_vec = self.n_sparse + 1
        d_int = n_vec * (n_vec - 1) // 2 + self.bot_mlp[-1]
        sizes = [d_int, *self.top_mlp]
        top = sum(sizes[i] * sizes[i + 1] + sizes[i + 1]
                  for i in range(len(sizes) - 1))
        return emb + bot + top


def init_dlrm_params(cfg: DLRMConfig, key):
    ks = jax.random.split(key, 3 + cfg.n_sparse)
    vocabs = cfg.resolved_vocabs()
    tables = [dense_init(ks[i], (v, cfg.embed_dim), cfg.embed_dim)
              for i, v in enumerate(vocabs)]
    n_vec = cfg.n_sparse + 1
    d_int = n_vec * (n_vec - 1) // 2 + cfg.bot_mlp[-1]
    return dict(
        tables=tables,
        bot=mlp_init(ks[-2], [cfg.n_dense, *cfg.bot_mlp]),
        top=mlp_init(ks[-1], [d_int, *cfg.top_mlp]),
    )


def embedding_bag(table, indices, weights=None, combiner="sum"):
    """table: [V, D]; indices: [B, L] -> [B, D].

    The manual EmbeddingBag: gather rows, reduce the bag axis.  With the
    table row-sharded over 'model', XLA turns the gather into an
    all-gather-free dynamic-slice + psum combine.
    """
    rows = jnp.take(table, indices, axis=0)         # [B, L, D]
    if weights is not None:
        rows = rows * weights[..., None]
    out = rows.sum(axis=1)
    if combiner == "mean":
        out = out / indices.shape[1]
    return out


def dlrm_forward(cfg: DLRMConfig, params, batch):
    """batch: dense [B, n_dense] f32; sparse [B, n_sparse, L] i32."""
    cd = cfg.compute_dtype
    dense, sparse = batch["dense"], batch["sparse"]
    B = dense.shape[0]
    x_bot = mlp_apply(params["bot"], dense.astype(cd), final_act=True)
    embs = [embedding_bag(params["tables"][f].astype(cd), sparse[:, f])
            for f in range(cfg.n_sparse)]
    vecs = jnp.stack([x_bot] + embs, axis=1)        # [B, F+1, D]
    if cfg.interaction == "dot":
        z = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
        iu, ju = np.triu_indices(vecs.shape[1], k=1)
        inter = z[:, iu, ju]                        # [B, F(F+1)/2]
    else:
        raise ValueError(cfg.interaction)
    top_in = jnp.concatenate([x_bot, inter], axis=-1)
    return mlp_apply(params["top"], top_in)[:, 0]   # logits [B]


def dlrm_loss(cfg: DLRMConfig, params, batch):
    logits = dlrm_forward(cfg, params, batch)
    y = batch["labels"].astype(jnp.float32)
    z = logits.astype(jnp.float32)
    # sigmoid BCE with logits
    loss = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return loss.mean()


# ---------------- retrieval (two-tower scoring) ----------------

def retrieval_score(cfg: DLRMConfig, params, batch):
    """Score one (or few) queries against a large candidate set.

    batch: dense [B, n_dense], sparse [B, n_sparse, L],
           candidates [C, D] — returns top-100 (scores, ids).
    """
    cd = cfg.compute_dtype
    dense, sparse = batch["dense"], batch["sparse"]
    x_bot = mlp_apply(params["bot"], dense.astype(cd), final_act=True)
    embs = [embedding_bag(params["tables"][f].astype(cd), sparse[:, f])
            for f in range(cfg.n_sparse)]
    user = x_bot + sum(embs)                        # [B, D] user tower
    cand = batch["candidates"].astype(cd)           # [C, D]
    scores = user @ cand.T                          # batched dot  [B, C]
    return jax.lax.top_k(scores, 100)
