"""Diffusion applications (the paper's `bfs-action`, plus future-work algs).

An app plugs into the generic ``OP_APP`` action.  All bundled apps follow a
*monotone relaxation* pattern so streaming updates never recompute from
scratch (the paper's central claim for dynamic BFS):

  relax(vals, incoming) -> (new_vals, changed)   # executed at the target
  edge_value(src_val, w)                          # value diffused along an edge
  propagate_on_insert(vals)                       # Listing 4 line 7 condition

``forward`` down the ghost chain always carries the slot's post-relax value
itself (same logical vertex, same value) — DESIGN §4.4.  The same property
makes the rhizome broadcast sound (DESIGN §4.5): an ``OP_RHIZOME_FWD``
carrying a canonical root's post-relax value is just another monotone
relax at each co-equal sibling root, so any interleaving of inserts,
broadcasts and link-acks converges to the same fixpoint, and the host
readback can ``combine`` (min) over the roots at any instant.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

# python float (not a jnp scalar) so app lambdas that close over it embed
# it as a literal — required for tracing inside the Pallas cycle megakernel
INF = 1e9


@dataclasses.dataclass(frozen=True)
class DiffusionApp:
    name: str
    # (vals[VN], incoming scalar) -> (new vals[VN], changed bool)
    relax: Callable
    # (emit source value scalar, edge weight scalar) -> scalar
    edge_value: Callable
    # vals[VN] -> bool : propagate on edge-insert? (Listing 4, line 7)
    propagate_on_insert: Callable
    init_val: float = 1e9
    n_vals: int = 1
    # host-side merge of one vertex's values across its rhizome roots;
    # must agree with relax's fixpoint direction (min for the bundled apps)
    combine: Callable = np.minimum


def _min_relax(vals, incoming):
    new0 = jnp.minimum(vals[..., 0], incoming)
    changed = incoming < vals[..., 0]
    return vals.at[..., 0].set(new0), changed


BFS = DiffusionApp(
    name="bfs",
    relax=_min_relax,
    edge_value=lambda v, w: v + 1.0,
    propagate_on_insert=lambda vals: vals[..., 0] < INF,
)

SSSP = DiffusionApp(
    name="sssp",
    relax=_min_relax,
    edge_value=lambda v, w: v + w,
    propagate_on_insert=lambda vals: vals[..., 0] < INF,
)

# Connected components by min-label propagation (undirected streams).
CC = DiffusionApp(
    name="cc",
    relax=_min_relax,
    edge_value=lambda v, w: v,
    propagate_on_insert=lambda vals: vals[..., 0] < INF,
)

# Ingestion-only mode: the paper's separate experiment with bfs-action
# propagation disabled (§5) to isolate streaming-insert time.
INGEST_ONLY = DiffusionApp(
    name="ingest_only",
    relax=lambda vals, incoming: (vals, jnp.zeros(vals.shape[:-1], bool)),
    edge_value=lambda v, w: v,
    propagate_on_insert=lambda vals: jnp.zeros(vals.shape[:-1], bool),
)

APPS = {a.name: a for a in (BFS, SSSP, CC, INGEST_ONLY)}
