"""The four assigned GNN architectures (public configs)."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchBundle, gnn_shapes
from repro.models.gnn import GNNConfig

# gatedgcn [arXiv:2003.00982] — benchmarking-GNNs config
GATEDGCN = GNNConfig(name="gatedgcn", kind="gatedgcn", n_layers=16,
                     d_hidden=70, d_in=1433, d_out=8, aggregator="gated")

# gcn-cora [arXiv:1609.02907] — the original 2-layer GCN on Cora
GCN_CORA = GNNConfig(name="gcn-cora", kind="gcn", n_layers=2, d_hidden=16,
                     d_in=1433, d_out=7, aggregator="mean")

# graphcast [arXiv:2212.12794] — encoder-processor-decoder mesh GNN
GRAPHCAST = GNNConfig(name="graphcast", kind="graphcast", n_layers=16,
                      d_hidden=512, mesh_refinement=6, n_vars=227,
                      d_in=227, d_out=227, aggregator="sum")

# meshgraphnet [arXiv:2010.03409]
MESHGRAPHNET = GNNConfig(name="meshgraphnet", kind="meshgraphnet",
                         n_layers=15, d_hidden=128, mlp_layers=2,
                         d_in=12, d_out=3, aggregator="sum")


def _smoke(cfg: GNNConfig) -> GNNConfig:
    return dataclasses.replace(
        cfg, n_layers=min(cfg.n_layers, 3), d_hidden=min(cfg.d_hidden, 16),
        d_in=8, d_out=4, n_vars=8, mesh_refinement=1)


def bundles():
    return [
        ArchBundle(a.name, "gnn", a, gnn_shapes(), (lambda c=a: _smoke(c)),
                   notes="paper technique directly applicable (DESIGN §5)")
        for a in (GATEDGCN, GCN_CORA, GRAPHCAST, MESHGRAPHNET)
    ]
