"""Virtual-lane flow control (DESIGN §7).

Pins the three contracts of the lane protocol:

* ``lanes=1`` is bit-exact with the recorded pre-lane engine on the full
  BFS stream, on both backends (``tests/data/pre_lanes_reference.json``
  was recorded from the engine immediately before the lane refactor);
* per-link round-robin arbitration is fair: a saturated (or blocked)
  lane can never starve a sibling lane's message beyond ``cfg.lanes``
  cycles per hop;
* the §4.2 head-of-line deadlock is gone: the hub-convergent stream
  completes at a small ``queue_cap`` with ``lanes >= 2`` (where
  ``lanes=1`` provably livelocks), values exact, both backends
  bit-exact against each other.
"""
import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, StreamingEngine
from repro.core.engine import _rc
from repro.core.msg import (OP_ALLOC, OP_APP, OP_INSERT_EDGE,
                            OP_LINK_RHIZOME, OP_RHIZOME_FWD, OP_SET_FUTURE,
                            make_msg)
from repro.core.reference import bfs_levels
from repro.core.routing import hop_stage, msg_lane
from repro.core.state import init_state
from repro.graph.streams import StreamSpec, hub_edges, make_stream

ONE = np.float32(1.0).view(np.int32)
REF = json.loads((pathlib.Path(__file__).parent
                  / "data" / "pre_lanes_reference.json").read_text())


# ---------------------------- lane assignment ----------------------------

def test_msg_lane_assignment():
    cfg = EngineConfig(height=4, width=4, n_vertices=16, lanes=4)
    dsts = jnp.arange(64, dtype=jnp.int32)
    for op in (OP_ALLOC, OP_SET_FUTURE, OP_LINK_RHIZOME, OP_RHIZOME_FWD):
        assert (np.asarray(msg_lane(cfg, jnp.int32(op), dsts)) == 0).all(), \
            "protocol traffic must ride the escape lane"
    for op in (OP_INSERT_EDGE, OP_APP):
        lanes = np.asarray(msg_lane(cfg, jnp.int32(op), dsts))
        assert (lanes >= 1).all() and (lanes < cfg.lanes).all()
        assert len(np.unique(lanes)) == cfg.lanes - 1  # hash spreads
    # a message's lane is a pure function of (op, dst): stable across hops
    one = EngineConfig(height=4, width=4, n_vertices=16, lanes=1)
    assert (np.asarray(msg_lane(one, jnp.int32(OP_APP), dsts)) == 0).all()


# ------------------------ arbitration fairness ---------------------------

def _lane_cfg(**kw):
    base = dict(height=4, width=4, n_vertices=16, edge_cap=2,
                ghost_slots=8, queue_cap=16, chan_cap=8, futq_cap=2,
                lanes=4)
    base.update(kw)
    return EngineConfig(**base)


def _put_chan(st, r, c, d, lane, msgs):
    """Host-side: place msgs into one lane's ring of cell (r, c)."""
    ch = np.array(st.ch)
    ch_n = np.array(st.ch_n)
    for i, m in enumerate(msgs):
        ch[r, c, d, lane, i] = m
    ch_n[r, c, d, lane] = len(msgs)
    return st._replace(ch=jnp.asarray(ch), ch_n=jnp.asarray(ch_n))


def test_blocked_lane_never_blocks_siblings():
    """A lane whose head is inadmissible (dst AQ closed to app traffic)
    is skipped by the arbiter: a sibling lane's message hops the SAME
    link on the very next cycle — the seed-era head-of-line block."""
    cfg = _lane_cfg()
    st = init_state(cfg)
    rows, cols = _rc(cfg)
    S = cfg.slots
    DIR_E = 3
    # lane 1: heads target cell (0,1) itself, whose AQ we close to app
    blocked = np.asarray(make_msg(OP_APP, 1 * S, 0, 0), np.int32)
    st = _put_chan(st, 0, 0, DIR_E, 1, [blocked, blocked])
    aq_n = np.asarray(st.aq_n).copy()
    aq_n[0, 1] = cfg.queue_cap - cfg.aq_reserve - cfg.sys_reserve  # closed
    st = st._replace(aq_n=jnp.asarray(aq_n))
    # lane 2: one message transiting (0,1) toward cell (0,2) — admissible
    free = np.asarray(make_msg(OP_APP, 2 * S, 0, 0), np.int32)
    st = _put_chan(st, 0, 0, DIR_E, 2, [free])

    st2, hops = hop_stage(cfg, st, rows, cols)
    assert int(hops) == 1
    assert int(st2.ch_n[0, 0, DIR_E, 2]) == 0, "admissible lane must hop"
    assert int(st2.ch_n[0, 1, DIR_E, 2]) == 1, "message entered next lane"
    assert int(st2.ch_n[0, 0, DIR_E, 1]) == 2, "blocked lane backpressured"


def test_saturated_lane_starvation_bound():
    """Round-robin bound: with every lane's head admissible, each lane is
    granted within ``cfg.lanes`` cycles per hop — a saturated lane cannot
    starve a sibling beyond that."""
    cfg = _lane_cfg()
    st = init_state(cfg)
    rows, cols = _rc(cfg)
    S = cfg.slots
    DIR_E = 3
    proto = np.asarray(make_msg(OP_SET_FUTURE, 1 * S + 1, 0, 0), np.int32)
    appm = np.asarray(make_msg(OP_APP, 1 * S, 0, 0), np.int32)
    st = _put_chan(st, 0, 0, DIR_E, 0, [proto, proto])
    for lane in (1, 2, 3):
        st = _put_chan(st, 0, 0, DIR_E, lane, [appm] * cfg.lane_capacity)
    before = np.asarray(st.ch_n)[0, 0, DIR_E].copy()
    for _ in range(cfg.lanes):
        st, _ = hop_stage(cfg, st, rows, cols)
    after = np.asarray(st.ch_n)[0, 0, DIR_E]
    # one grant per cycle, and after `lanes` cycles EVERY lane got exactly
    # one (the arbiter pointer sweeps all of them — no lane starved)
    assert (before - after == 1).all(), (before, after)


# ----------------- lanes=1 bit-exactness vs the pre-PR engine ------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_lanes1_bit_exact_vs_pre_pr_engine(backend):
    """The lane refactor at ``lanes=1`` replays the recorded pre-PR
    engine exactly: per-increment cycle/hop/exec/stall/alloc counters and
    final BFS values, over the full 3-increment stream."""
    incs = make_stream(StreamSpec(**REF["spec"]))
    eng = StreamingEngine(EngineConfig(backend=backend, **REF["cfg"]), "bfs")
    eng.seed(0, 0.0)
    rows = []
    for e in incs:
        r = eng.run_increment(e, max_cycles=500_000)
        rows.append(dict(cycles=r.cycles, hops=r.hops, execs=r.execs,
                         stalls=r.stalls, allocs=r.allocs))
    want = REF["backends"][backend]
    assert rows == want["increments"]
    np.testing.assert_array_equal(eng.values(128), np.array(want["values"]))


# --------------- the §4.2 hub deadlock is gone with lanes ----------------

def _hub_stream(n=128, degree=200, seed=3):
    e = hub_edges(n, 0, degree, seed=seed)
    return np.concatenate([e, np.full((len(e), 1), ONE, np.int64)],
                          1).astype(np.int32)


def _hub_cfg(**kw):
    base = dict(height=8, width=8, n_vertices=128, edge_cap=4,
                ghost_slots=48, queue_cap=20, chan_cap=16, futq_cap=4,
                io_stream_cap=2048, chunk=64)
    base.update(kw)
    return EngineConfig(**base)


def test_hub_livelocks_without_lanes():
    """Control: at the small queue_cap the single-FIFO channel machine
    hits the §4.2 head-of-line deadlock and the detector fires."""
    eng = StreamingEngine(_hub_cfg(lanes=1), "bfs")
    eng.seed(0, 0.0)
    with pytest.raises(RuntimeError, match="livelock"):
        eng.run_increment(_hub_stream(), max_cycles=500_000)


@pytest.mark.parametrize("lanes", [2, 4])
def test_hub_completes_with_lanes_small_queue(lanes):
    """With virtual lanes the same hub-convergent stream completes at the
    same small queue_cap, values exact vs NetworkX."""
    edges = _hub_stream()
    eng = StreamingEngine(_hub_cfg(lanes=lanes), "bfs")
    eng.seed(0, 0.0)
    r = eng.run_increment(edges, max_cycles=500_000)
    assert r.cycles > 0
    np.testing.assert_array_equal(eng.values(128), bfs_levels(128, edges, 0))


def test_lanes4_backend_parity_hub():
    """jnp and the Pallas megakernel stay bit-exact per state leaf with
    the full lane protocol engaged (arbiter + escape lane + parking)."""
    edges = _hub_stream()
    want = bfs_levels(128, edges, 0)
    finals = {}
    for backend in ("jnp", "pallas"):
        eng = StreamingEngine(_hub_cfg(lanes=4, backend=backend), "bfs")
        eng.seed(0, 0.0)
        r = eng.run_increment(edges, max_cycles=500_000)
        np.testing.assert_array_equal(eng.values(128), want)
        finals[backend] = (eng.state, r.cycles)
    assert finals["jnp"][1] == finals["pallas"][1]
    for name, a, b in zip(finals["jnp"][0]._fields, finals["jnp"][0],
                          finals["pallas"][0]):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state leaf '{name}' diverged between backends")
