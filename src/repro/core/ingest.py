"""Streaming edge ingestion via IO cells (paper §2, §4 "Graph Construction").

One IO cell per chip column, attached to the row-0 cell of its column.
Every cycle each IO cell reads the next edge of its residual stream,
creates the registered ``insert-edge-action`` and sends it to its connected
Compute Cell — entering the routing fabric there (action queue if the
target vertex lives on that cell, else the proper YX outgoing channel).
Backpressure stalls the IO cell (it retries the same edge next cycle).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import rings
from repro.core.alloc import rhizome_addr
from repro.core.config import EngineConfig
from repro.core.msg import (OP_INSERT_EDGE, OP_REPAIR, make_msg, pad_msg,
                            seal_msg)
from repro.core.routing import (deliver, manhattan_hops, msg_lane,
                                yx_target_buffer)
from repro.core.state import MachineState, TM_IO, root_addr


def load_stream(cfg: EngineConfig, st: MachineState, edges: np.ndarray,
                limit: int | None = None):
    """Distribute an increment's edges round-robin over the IO cells.

    edges: int32 [m, 3] rows of (src vid, dst vid, weight bits).
    Any residue from a previous increment is preserved (appended after).

    Returns ``(state, spill)``: edges that did not fit the per-IO-cell
    residual-stream capacity are returned (in arrival order) instead of
    asserting — the engine re-loads them once the loaded prefix has been
    consumed (spill-to-next-pass residue, DESIGN §4.2).

    ``limit`` caps the number of NEW edges admitted this call (residue
    always reloads in full); the rest spill.  This is the ingest-guard
    backpressure knob (DESIGN §9): the engine lowers the limit when the
    ``tm_hiw`` action-queue hi-water mark shows the fabric saturating,
    so ingest throttles instead of wedging the machine.
    """
    IO, L = cfg.io_cells, cfg.io_stream_cap
    io_edges = np.asarray(st.io_edges)
    io_n = np.asarray(st.io_n).copy()
    io_pos = np.asarray(st.io_pos).copy()
    # compact: drop consumed prefix
    new_edges = np.zeros_like(io_edges)
    new_n = np.zeros_like(io_n)
    for i in range(IO):
        rem = io_edges[i, io_pos[i]:io_n[i]]
        new_edges[i, :len(rem)] = rem
        new_n[i] = len(rem)
    edges = np.asarray(edges, np.int32).reshape(-1, 3)
    spill = []
    admitted = 0
    for k, e in enumerate(edges):
        i = k % IO
        if new_n[i] >= L or (limit is not None and admitted >= limit):
            spill.append(e)
            continue
        new_edges[i, new_n[i]] = e
        new_n[i] += 1
        admitted += 1
    st = st._replace(io_edges=jnp.asarray(new_edges),
                     io_n=jnp.asarray(new_n),
                     io_pos=jnp.zeros_like(st.io_pos))
    return st, (np.stack(spill) if spill
                else np.zeros((0, 3), np.int32))


def io_stage(cfg: EngineConfig, st: MachineState, rows, cols):
    """One injection attempt per IO cell per cycle (vectorized on row 0)."""
    S, Q = cfg.slots, cfg.queue_cap
    IO = cfg.io_cells  # == width
    pend = st.io_pos < st.io_n                       # [IO]
    cur = st.io_edges[jnp.arange(IO), jnp.minimum(st.io_pos, cfg.io_stream_cap - 1)]

    r0 = jnp.zeros((IO,), jnp.int32)
    c0 = jnp.arange(IO, dtype=jnp.int32)
    # Route the insert to the nearest rhizome root of the src vertex,
    # under a per-IO-cell round-robin preference (DESIGN §4.5): the
    # rotation shards a hub's inserts evenly over its co-equal roots
    # (pure nearest would collapse onto whichever root sits closest to
    # the IO row and re-serialize the hub), while the routing distance
    # overrides the rotation when another root is more than half a chip
    # diameter closer.  With rhizome_cap=1 this is exactly the canonical
    # root.  Edge destinations always name the canonical root: the
    # application diffusion relaxes there and fans out to siblings.
    R = cfg.rhizome_cap
    ks = jnp.arange(R, dtype=jnp.int32)[None, :]
    cand = rhizome_addr(cfg, cur[:, 0:1], ks)        # [IO, R]
    dist = manhattan_hops(cfg, cand // S, r0[:, None], c0[:, None])
    half_diam = max(1, (cfg.height + cfg.width - 2) // 2)
    pref = (ks - st.io_pos[:, None]) % R             # 0 = rotation favorite
    best = jnp.argmin(dist + pref * half_diam, axis=1)
    tgt = jnp.take_along_axis(cand, best[:, None], axis=1)[:, 0]
    msg = make_msg(OP_INSERT_EDGE, tgt, root_addr(cfg, cur[:, 1]), cur[:, 2])
    if cfg.qbatch > 1:
        # insert-edge payload is (dst, weight) only — the query-axis
        # extension words of a qbatch > 1 machine are dead here (§10)
        msg = pad_msg(msg, cfg.msg_words)
    if cfg.faults is not None:
        # repair-injection sentinel (DESIGN §9): a stream row with a
        # NEGATIVE dst word is not an edge but a recovery relax —
        # ``(vid, -(k+1), value_bits)`` re-injects the durable value of
        # ``vid`` at its rhizome root ``k`` as an OP_REPAIR, reusing the
        # whole IO admission/backpressure machinery for the repair pass
        rp = cur[:, 1] < 0
        k_rp = -cur[:, 1] - 1
        rp_tgt = rhizome_addr(cfg, cur[:, 0], k_rp)
        tgt = jnp.where(rp, rp_tgt, tgt)
        msg = jnp.where(rp[:, None],
                        make_msg(OP_REPAIR, rp_tgt, cur[:, 2]), msg)
        msg = seal_msg(msg)

    tb = yx_target_buffer(cfg, tgt // S, r0, c0)     # [IO]

    # delivery on the row-0 slices (deliver is shape-polymorphic: [IO]
    # leading batch dim here, the full [H,W] grid in hop/staging); the
    # injected inserts are application traffic, so they take a
    # destination-hashed data lane and the app-level AQ reserve rule
    aq0, aqn0, ch0, chn0, accepted = deliver(
        cfg, st.aq[0], st.aq_n[0], st.aq_head[0],
        st.ch[0], st.ch_n[0], st.ch_head[0], msg, tb,
        msg_lane(cfg, msg[..., 0], msg[..., 1]), pend,
        rings.ring_free(st.aq_n[0], Q, cfg.aq_reserve + cfg.sys_reserve))
    aq = st.aq.at[0].set(aq0)
    aq_n = st.aq_n.at[0].set(aqn0)
    ch = st.ch.at[0].set(ch0)
    ch_n = st.ch_n.at[0].set(chn0)

    io_pos = st.io_pos + accepted.astype(jnp.int32)
    st = st._replace(aq=aq, aq_n=aq_n, ch=ch, ch_n=ch_n, io_pos=io_pos)
    if cfg.telemetry:
        # IO cells sit on row 0 (one per column == IO)
        st = st._replace(tm_cell=st.tm_cell.at[0, :, TM_IO]
                         .add(accepted.astype(jnp.int32)))
    return st
