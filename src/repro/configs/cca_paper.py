"""The paper's own workload as an architecture: the AM-CCA streaming
dynamic-graph engine.  Shapes scale the chip from the paper's 32x32 to a
pod-scale 512x512 cellular grid (one tile of cells per TPU chip).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchBundle, shape
from repro.core.config import EngineConfig

CCA_32 = EngineConfig(height=32, width=32, n_vertices=50_000, edge_cap=8,
                      ghost_slots=256, queue_cap=32, chan_cap=8, futq_cap=8,
                      io_stream_cap=8192, chunk=128)


def cca_shapes():
    return (
        # the paper's chip: 32x32 CCs, GraphChallenge 50K-vertex stream
        shape("chip_32x32_50k", "cca_stream", height=32, width=32,
              n_vertices=50_000, stream_edges=102_000),
        # pod-scale grids (one 32x32 tile of cells per device on 16x16 mesh)
        shape("chip_512x512_1m", "cca_stream", height=512, width=512,
              n_vertices=1_000_000, stream_edges=1_000_000),
        shape("chip_1024x512_2m", "cca_stream", height=1024, width=512,
              n_vertices=2_000_000, stream_edges=2_000_000),
    )


def engine_config_for(spec) -> EngineConfig:
    d = dict(spec.dims)
    return dataclasses.replace(
        CCA_32, height=d["height"], width=d["width"],
        n_vertices=d["n_vertices"],
        ghost_slots=max(16, 4 * d["n_vertices"] // (d["height"] * d["width"])),
        io_stream_cap=max(1024, 2 * d["stream_edges"] // d["width"]))


def _smoke():
    return dataclasses.replace(CCA_32, height=4, width=4, n_vertices=32,
                               ghost_slots=16, io_stream_cap=128, chunk=32)


def bundles():
    return [ArchBundle("cca-streaming-bfs", "cca", CCA_32, cca_shapes(),
                       _smoke,
                       notes="the paper's contribution itself; "
                             "grid sharded over mesh axes, hops lower to "
                             "collective-permute")]
