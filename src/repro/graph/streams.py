"""Streaming dynamic graph generators — GraphChallenge-style (paper §4).

The paper uses MIT GraphChallenge stochastic-block-partition streaming
graphs (Table 1): 50K/500K vertices, ~1.0M/10.2M edges, delivered in ten
increments under two sampling regimes:

  * **Edge sampling**   — edges arrive in random (real-world observation)
    order, so increments have near-equal size.
  * **Snowball sampling** — edges arrive as discovered by an expanding
    frontier from a start vertex, so increments grow monotonically
    (the paper's Table 1 shows 37K -> 191K for the 50K graph).

The datasets are offline here, so we synthesize stochastic-block-model
graphs of the same shape and stream them with the same two samplers.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    n_vertices: int = 50_000
    n_edges: int = 1_000_000
    n_blocks: int = 32          # SBM community count
    p_in_over_p_out: float = 16.0
    increments: int = 10
    sampling: str = "edge"      # "edge" | "snowball"
    seed: int = 0
    symmetric: bool = False     # insert both directions


def sbm_edges(spec: StreamSpec) -> np.ndarray:
    """Sample ~n_edges unique directed edges of a stochastic block model."""
    rng = np.random.default_rng(spec.seed)
    V, B = spec.n_vertices, spec.n_blocks
    block = rng.integers(0, B, size=V)
    m = 0
    chunks = []
    seen = set()
    # rejection-sample: propose intra-block with prob prop. to p_in ratio
    p_intra = spec.p_in_over_p_out / (spec.p_in_over_p_out + B - 1)
    while m < spec.n_edges:
        k = min(4 * (spec.n_edges - m) + 1024, 4_000_000)
        src = rng.integers(0, V, size=k)
        intra = rng.random(k) < p_intra
        # intra: dst from same block; inter: uniform
        dst = rng.integers(0, V, size=k)
        # resample intra dsts from src's block by jittering within block lists
        order = np.argsort(block, kind="stable")
        starts = np.searchsorted(block[order], np.arange(B))
        ends = np.searchsorted(block[order], np.arange(B), side="right")
        b = block[src]
        lo, hi = starts[b], ends[b]
        pick = lo + (rng.integers(0, 1 << 30, size=k) % np.maximum(hi - lo, 1))
        dst = np.where(intra, order[pick], dst)
        ok = src != dst
        src, dst = src[ok], dst[ok]
        for s, d in zip(src, dst):
            key = (int(s) << 32) | int(d)
            if key not in seen:
                seen.add(key)
                chunks.append((s, d))
                m += 1
                if m >= spec.n_edges:
                    break
    e = np.asarray(chunks, dtype=np.int64)
    return e.astype(np.int32)


def edge_sampled_stream(edges: np.ndarray, increments: int,
                        seed: int = 0) -> list[np.ndarray]:
    """Random arrival order, equal-size increments (Table 1 'Edge')."""
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(len(edges))
    parts = np.array_split(perm, increments)
    return [edges[p] for p in parts]


def snowball_stream(edges: np.ndarray, increments: int, source: int = 0,
                    seed: int = 0) -> list[np.ndarray]:
    """Edges arrive as discovered by BFS from `source` (Table 1 'Snowball').

    Produces monotonically growing increments like the paper by splitting
    the discovery order at quadratically spaced cut points.
    """
    n = int(max(edges[:, 0].max(), edges[:, 1].max())) + 1
    # adjacency (undirected discovery like the GraphChallenge snowball)
    order = np.zeros(len(edges), dtype=np.int64)
    adj_idx = {}
    for i, (s, d) in enumerate(edges):
        adj_idx.setdefault(int(s), []).append(i)
        adj_idx.setdefault(int(d), []).append(i)
    seen_v = np.zeros(n, bool)
    seen_e = np.zeros(len(edges), bool)
    outq = [source]
    seen_v[source] = True
    pos = 0
    k = 0
    while outq:
        nxt = []
        for v in outq:
            for ei in adj_idx.get(v, ()):
                if not seen_e[ei]:
                    seen_e[ei] = True
                    order[k] = ei
                    k += 1
                    s, d = edges[ei]
                    for u in (int(s), int(d)):
                        if not seen_v[u]:
                            seen_v[u] = True
                            nxt.append(u)
        outq = nxt
    # disconnected leftovers arrive last
    rest = np.nonzero(~seen_e)[0]
    order[k:k + len(rest)] = rest
    k += len(rest)
    order = order[:k]
    # quadratic cut points -> growing increments (paper Table 1 pattern)
    w = np.arange(1, increments + 1, dtype=np.float64)
    cuts = np.cumsum(w / w.sum()) * k
    cuts = np.unique(np.round(cuts).astype(np.int64))[:-1]
    return [edges[p] for p in np.split(order, cuts)]


def make_stream(spec: StreamSpec) -> list[np.ndarray]:
    edges = sbm_edges(spec)
    if spec.symmetric:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    if spec.sampling == "edge":
        incs = edge_sampled_stream(edges, spec.increments, spec.seed)
    elif spec.sampling == "snowball":
        incs = snowball_stream(edges, spec.increments, source=0,
                               seed=spec.seed)
    else:
        raise ValueError(spec.sampling)
    # attach unit weights (bit pattern of 1.0f)
    one = np.float32(1.0).view(np.int32)
    return [np.concatenate([e, np.full((len(e), 1), one, np.int32)], axis=1)
            for e in incs]
