"""The paper's full experiment: GraphChallenge-style streaming dynamic
BFS on a 32x32 AM-CCA chip — Edge vs Snowball sampling, 10 increments,
ingestion-only vs ingestion+BFS, verified against NetworkX.

  PYTHONPATH=src python examples/streaming_bfs.py [--vertices 2000]
"""
import argparse

import numpy as np

from repro.core import EngineConfig, StreamingEngine
from repro.core.energy import DEFAULT as ENERGY
from repro.core.reference import bfs_levels
from repro.graph.streams import StreamSpec, make_stream


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=2000)
    ap.add_argument("--edges", type=int, default=20_000)
    ap.add_argument("--sampling", default="edge",
                    choices=["edge", "snowball"])
    ap.add_argument("--kind", default="sbm", choices=["sbm", "rmat"],
                    help="rmat = power-law skew (pair with --rhizomes)")
    ap.add_argument("--rhizomes", type=int, default=1,
                    help="co-equal roots per vertex (DESIGN §4.5)")
    ap.add_argument("--lanes", type=int, default=1,
                    help="virtual lanes per mesh link (DESIGN §7); "
                         ">=2 enables the escape lane + transit parking")
    args = ap.parse_args()

    spec = StreamSpec(n_vertices=args.vertices, n_edges=args.edges,
                      increments=10, sampling=args.sampling, seed=1,
                      kind=args.kind)
    incs = make_stream(spec)
    cfg = EngineConfig(height=32, width=32, n_vertices=args.vertices,
                       edge_cap=8,
                       ghost_slots=max(32, 3 * args.vertices // 1024),
                       io_stream_cap=2 ** 20, chunk=512,
                       rhizome_cap=args.rhizomes, lanes=args.lanes)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)

    total_cycles = 0
    print(f"{args.kind}/{args.sampling}-sampled stream, "
          f"{args.vertices} vertices, "
          f"{sum(len(e) for e in incs)} edges, 10 increments, "
          f"rhizome_cap={args.rhizomes}, lanes={args.lanes}")
    for i, e in enumerate(incs):
        r = eng.run_increment(e, max_cycles=2_000_000,
                              collect_traces=True)
        total_cycles += r.cycles
        peak = r.active_per_cycle.max() if len(r.active_per_cycle) else 0
        print(f"  increment {i}: {len(e):6d} edges  {r.cycles:7d} cycles  "
              f"peak active cells {peak}/1024  stalls {r.stalls}")

    want = bfs_levels(args.vertices, np.concatenate(incs), 0)
    got = eng.values(args.vertices)
    assert (got == want).all(), "mismatch vs NetworkX!"
    print("BFS levels verified against NetworkX (paper §4 methodology).")
    t = eng.totals
    uj = ENERGY.estimate_uj(hops=t["hops"], execs=t["execs"],
                            allocs=t["allocs"],
                            injects=sum(len(e) for e in incs))
    print(f"total: {total_cycles} cycles = "
          f"{ENERGY.cycles_to_us(total_cycles):.1f} us @1GHz, "
          f"~{uj:.0f} uJ (Table 2 analogue)")
    print("vertex object stats:", eng.vertex_object_stats())


if __name__ == "__main__":
    main()
