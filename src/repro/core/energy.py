"""Energy/time estimation (paper Table 2).

The paper reuses the cost model of its ref [4] for a 590 mm^2, 1 GHz,
32x32-CC chip.  The exact constants aren't in the paper text; we calibrate
the per-event constants so that the 50K-vertex Edge-sampling ingestion run
(~1.02M inserted edges, ~22 us, 1355 uJ in Table 2) is matched to within
~10% on our engine's event counts, and report OUR event counts times these
constants.  Derivation in benchmarks/bench_energy.py.
"""
from __future__ import annotations

import dataclasses

CLOCK_HZ = 1e9


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    pj_per_hop: float = 40.0       # one message, one mesh link
    pj_per_action: float = 150.0   # action execute (one compute op)
    pj_per_alloc: float = 300.0    # ghost allocation (memory mgmt)
    pj_per_inject: float = 60.0    # IO cell -> CC transfer

    def estimate_uj(self, *, hops: int, execs: int, allocs: int,
                    injects: int) -> float:
        pj = (hops * self.pj_per_hop + execs * self.pj_per_action
              + allocs * self.pj_per_alloc + injects * self.pj_per_inject)
        return pj / 1e6

    @staticmethod
    def cycles_to_us(cycles: int) -> float:
        return cycles / CLOCK_HZ * 1e6


DEFAULT = EnergyModel()
