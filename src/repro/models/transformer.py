"""Decoder-only LM: GQA + RoPE (+ optional qk-norm), dense or MoE FFN.

Design notes (MaxText-style, sized for 1000+-chip runs):

* parameters are **stacked over layers** and the forward is a
  ``lax.scan`` over the stack -> HLO size is O(1) in depth, which keeps
  512-device dry-run compiles fast and enables uniform remat;
* attention is **chunked online-softmax** (flash) even in the pure-XLA
  path, so peak memory never materializes the [T, T] score matrix; on
  real TPUs the Pallas kernel (repro.kernels.flash_attention.ops) is the
  drop-in replacement for flash_attention_xla (validated against the
  same oracle in tests/test_kernels.py);
* all matmuls run in bf16 with f32 accumulation; params live in f32
  (master copy) unless cfg.param_dtype says otherwise.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, layer_norm, rms_norm


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: int = 0            # 0 -> d_model // n_heads
    norm: str = "rms"            # "rms" | "ln"
    qk_norm: bool = False
    gated_ffn: bool = True       # SwiGLU (llama-family); False -> GELU MLP
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0           # 0 -> dense FFN
    top_k: int = 2
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- numerics ---
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_chunk: int = 512
    # --- distribution ---
    moe_impl: str = "dense"      # "dense" (GShard einsum, small S) |
                                 # "ep" (shard_map expert parallelism)

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        D, F, H, K, dh = (self.d_model, self.d_ff, self.n_heads,
                          self.n_kv_heads, self.dh)
        attn = D * H * dh + 2 * D * K * dh + H * dh * D
        ffn = D * F * (3 if self.gated_ffn else 2)
        if self.n_experts:
            moe = self.n_experts * ffn + D * self.n_experts
            ffn = moe + (ffn if self.dense_residual else 0)
        per_layer = attn + ffn + 2 * D
        return self.vocab * D * 2 + self.n_layers * per_layer + D

    def n_active_params(self) -> int:
        """Active (per-token) params — MoE uses top_k experts only."""
        if not self.n_experts:
            return self.n_params()
        D, F = self.d_model, self.d_ff
        ffn1 = D * F * (3 if self.gated_ffn else 2)
        inactive = self.n_layers * (self.n_experts - self.top_k) * ffn1
        return self.n_params() - max(inactive, 0)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def padded_heads(cfg: LMConfig) -> int:
    """Physical head count (§Perf iter 3 — REFUTED and disabled).

    Zero-padding heads to a TP multiple (arctic 56->64) removed the
    attention dK/dQ all-reduces (-1.9 GB/dev) but the replacement
    row-parallel psums + 14% bigger FSDP gathers cost more than it saved
    (+4.2 GB/dev all-gather).  Sequence-sharded attention (the fallback
    when H %% TP != 0) is the better regime for these archs; kept here
    (returning the unpadded count) with the measurement recorded in
    EXPERIMENTS.md so the refutation is reproducible."""
    return cfg.n_heads


def init_lm_params(cfg: LMConfig, key) -> dict:
    D, F, H, K, dh, L = (cfg.d_model, cfg.d_ff, padded_heads(cfg),
                         cfg.n_kv_heads, cfg.dh, cfg.n_layers)
    keys = jax.random.split(key, 12)
    pd = cfg.param_dtype

    def stack(k, shape, fan_in):
        if L == 0:  # cost-extraction lowers use 0-layer variants
            return jnp.zeros((0, *shape), pd)
        ks = jax.random.split(k, L)
        return jnp.stack([dense_init(ks[i], shape, fan_in, pd)
                          for i in range(L)])

    layers = dict(
        wq=stack(keys[0], (D, H * dh), D),
        wk=stack(keys[1], (D, K * dh), D),
        wv=stack(keys[2], (D, K * dh), D),
        wo=stack(keys[3], (H * dh, D), H * dh),
        ln1=jnp.ones((L, D), pd),
        ln2=jnp.ones((L, D), pd),
    )
    if cfg.norm == "ln":
        layers["ln1b"] = jnp.zeros((L, D), pd)
        layers["ln2b"] = jnp.zeros((L, D), pd)
    if cfg.qk_norm:
        layers["qnorm"] = jnp.ones((L, dh), pd)
        layers["knorm"] = jnp.ones((L, dh), pd)

    def ffn_params(k, prefix, e=None):
        ks = jax.random.split(k, 3)
        shp = (L, D, F) if e is None else (L, e, D, F)
        shp_out = (L, F, D) if e is None else (L, e, F, D)

        def stk(kk, shape, fan_in):
            if L == 0:
                return jnp.zeros((0, *shape[1:]), pd)
            return jnp.stack([dense_init(k2, shape[1:], fan_in, pd)
                              for k2 in jax.random.split(kk, L)])

        p = {prefix + "wi": stk(ks[0], shp, D)}
        if cfg.gated_ffn:
            p[prefix + "wg"] = stk(ks[1], shp, D)
        p[prefix + "wo"] = stk(ks[2], shp_out, F)
        return p

    if cfg.n_experts:
        layers.update(ffn_params(keys[4], "moe_", cfg.n_experts))
        layers["router"] = stack(keys[5], (D, cfg.n_experts), D)
        if cfg.dense_residual:
            layers.update(ffn_params(keys[6], "ffn_"))
    else:
        layers.update(ffn_params(keys[4], "ffn_"))

    return dict(
        embed=dense_init(keys[7], (cfg.vocab, D), D, pd),
        unembed=dense_init(keys[8], (D, cfg.vocab), D, pd),
        final_norm=jnp.ones((D,), pd),
        layers=layers,
    )


# --------------------------------------------------------------------------
# rope / norm helpers
# --------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: [B, T, H, dh]; positions: [B, T].

    cos/sin are computed in f32 but CAST to x.dtype before touching x:
    otherwise every rope output (and its bwd cotangent) is silently f32,
    doubling the attention-path collective/memory bytes (found via the
    dry-run HLO collective audit — EXPERIMENTS.md §Perf iter 1).
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,T,half]
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _norm(cfg, x, scale, bias=None):
    if cfg.norm == "ln":
        return layer_norm(x, scale, bias)
    return rms_norm(x, scale)


@jax.custom_vjp
def ct_cast(x):
    """Identity that forces the COTANGENT back to x's dtype.

    f32-accumulating einsums (preferred_element_type=f32) emit f32
    cotangents which then flow through the whole backward residual/QKV
    stream — doubling every backward all-gather/all-reduce (arctic HLO
    audit, EXPERIMENTS.md §Perf iter 1).  Inserting ct_cast at the layer
    and attention inputs pins the backward stream to bf16.
    """
    return x


def _ct_fwd(x):
    return x, jnp.zeros((0,), x.dtype)  # dtype token (valid JAX residual)


def _ct_bwd(token, g):
    return (g.astype(token.dtype),)


ct_cast.defvjp(_ct_fwd, _ct_bwd)


def wcast(w, cfg, *spec):
    """Cast a weight to compute dtype AND pin the cast output to the
    weight's own sharding.  Without the pin, GSPMD may all-gather the f32
    master weight and convert after — 2x the FSDP gather bytes (found in
    the arctic HLO audit, EXPERIMENTS.md §Perf iter 1)."""
    from repro.dist.ctx import constrain
    return constrain(w.astype(cfg.compute_dtype), *spec)


# --------------------------------------------------------------------------
# attention (chunked online softmax — flash, in plain XLA)
# --------------------------------------------------------------------------

def flash_attention_xla(q, k, v, *, causal=True, chunk=512, q_offset=0):
    """q: [B,Tq,H,dh], k/v: [B,Tk,Kh,dh] (GQA: H % Kh == 0).

    Scans KV chunks with a running (max, sum, acc) — peak memory is
    O(Tq * chunk), never [Tq, Tk].  Computes in flat-H layout (KV heads
    broadcast per chunk): one head axis shards cleanly over `model` for
    every assigned head count (DESIGN §6); when H doesn't divide the TP
    degree the q-time axis is sharded instead (sequence parallelism).
    """
    from repro.dist.ctx import constrain, model_size
    B, Tq, H, dh = q.shape
    Tk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = 1.0 / np.sqrt(dh)
    qf = (q * scale).astype(jnp.bfloat16)
    tp = model_size()
    if H % tp == 0:
        qf = constrain(qf, "dp", None, "model", None)
    elif Tq % tp == 0:
        qf = constrain(qf, "dp", "model", None, None)
    nchunks = -(-Tk // chunk)
    pad = nchunks * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ks = k.reshape(B, nchunks, chunk, Kh, dh)
    vs = v.reshape(B, nchunks, chunk, Kh, dh)
    rows = q_offset + jnp.arange(Tq)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, ci = inp
        # Resharding (if any) must happen on the COMPACT [*, Kh, dh] KV
        # chunk, not on the H-broadcast copy — for 56:8 GQA that is 7x
        # fewer gathered bytes (EXPERIMENTS.md §Perf iter 2).
        kc = constrain(kc.astype(jnp.bfloat16), "dp", None, None, None)
        vc = constrain(vc.astype(jnp.bfloat16), "dp", None, None, None)
        # broadcast KV heads to flat H (virtual repeat; fused by XLA)
        kcf = jnp.broadcast_to(kc[:, :, :, None],
                               (B, chunk, Kh, G, dh)).reshape(B, chunk, H, dh)
        vcf = jnp.broadcast_to(vc[:, :, :, None],
                               (B, chunk, Kh, G, dh)).reshape(B, chunk, H, dh)
        s = jnp.einsum("bthd,bchd->bthc", qf, kcf,
                       preferred_element_type=jnp.float32)
        cols = ci * chunk + jnp.arange(chunk)
        mask = cols[None, :] <= rows[:, None] if causal else \
            jnp.broadcast_to(cols[None, :] >= 0, (Tq, chunk))
        mask = mask & (cols[None, :] < Tk)
        s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
        m2 = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m2)
        p = jnp.exp(s - m2[..., None])
        l2 = l * corr + p.sum(axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bthc,bchd->bthd", p.astype(jnp.bfloat16), vcf,
            preferred_element_type=jnp.float32)
        return (m2, l2, acc2), None

    m0 = jnp.full((B, Tq, H), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Tq, H), jnp.float32)
    a0 = jnp.zeros((B, Tq, H, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0),
         jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths):
    """Single-token decode: q [B,1,H,dh]; caches [B,T,Kh,dh]; lengths [B].

    Plain (non-chunked) — decode is linear in T; with the cache's T axis
    sharded this is flash-decoding: partial softmax merged by the psum XLA
    inserts for the reductions over the sharded axis.
    """
    B, _, H, dh = q.shape
    Kh = k_cache.shape[2]
    G = H // Kh
    scale = 1.0 / np.sqrt(dh)
    qf = (q[:, 0] * scale).reshape(B, Kh, G, dh).astype(jnp.bfloat16)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    T = k_cache.shape[1]
    mask = jnp.arange(T)[None, :] < lengths[:, None]          # [B,T]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(jnp.bfloat16),
                     v_cache.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh)


# --------------------------------------------------------------------------
# layer / model forward
# --------------------------------------------------------------------------

def _ffn_dense(cfg: LMConfig, lp, x, prefix="ffn_"):
    wi = wcast(lp[prefix + "wi"], cfg, "dp", "model")
    wo = wcast(lp[prefix + "wo"], cfg, "model", "dp")
    if cfg.gated_ffn:
        wg = wcast(lp[prefix + "wg"], cfg, "dp", "model")
        h = (x @ wi) * jax.nn.silu(x @ wg)
    else:
        h = jax.nn.gelu(x @ wi)
    return h @ wo


def _ffn_moe(cfg: LMConfig, lp, x):
    """Top-k routed experts, GShard-style dense dispatch einsums.

    x: [B,T,D] -> combine over top_k expert outputs.  Experts dim is
    sharded over the 'model'/'expert' mesh axis; the dispatch einsum
    becomes an all-to-all under GSPMD.
    """
    cd = cfg.compute_dtype
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    S = B * T
    xs = x.reshape(S, D)
    logits = (xs @ lp["router"].astype(cd)).astype(jnp.float32)  # [S,E]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, K)                          # [S,K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    cap = int(np.ceil(S * K * cfg.capacity_factor / E))
    cap = max(cap, 4)
    # position of each (token, k) within its expert
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)             # [S,K,E]
    flat = onehot.reshape(S * K, E)
    pos = jnp.cumsum(flat, axis=0) - flat                          # [S*K,E]
    pos = (pos * flat).sum(-1).reshape(S, K)                       # [S,K]
    keep = pos < cap
    # dispatch tensor [S, K, E, cap] is huge; build [S,E,cap] combining K
    disp = jnp.zeros((S, E, cap), cd)
    sidx = jnp.arange(S)[:, None].repeat(K, 1)
    disp = disp.at[sidx, topi, jnp.minimum(pos, cap - 1)].add(
        keep.astype(cd))
    # expert inputs [E, cap, D]
    ein = jnp.einsum("sec,sd->ecd", disp, xs.astype(cd))
    if cfg.gated_ffn:
        h = jnp.einsum("ecd,edf->ecf", ein, lp["moe_wi"].astype(cd)) \
            * jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein,
                                     lp["moe_wg"].astype(cd)))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", ein,
                                   lp["moe_wi"].astype(cd)))
    eout = jnp.einsum("ecf,efd->ecd", h, lp["moe_wo"].astype(cd))
    # combine weights: scatter the (normalized) gate values into [S,E,cap]
    comb = jnp.zeros((S, E, cap), cd)
    comb = comb.at[sidx, topi, jnp.minimum(pos, cap - 1)].add(
        (keep * topv).astype(cd))
    out = jnp.einsum("sec,ecd->sd", comb, eout)
    # aux load-balancing loss (Switch): E * sum_e (frac_tokens_e * frac_prob_e)
    frac_t = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), 0)
    frac_p = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(frac_t * frac_p)
    return out.reshape(B, T, D), aux


def _ffn_moe_ep(cfg: LMConfig, lp, x):
    """Expert-parallel MoE via shard_map (DESIGN §6).

    Experts are sharded over the `model` axis.  Activations are
    TP-replicated over `model`, so *no all-to-all is needed*: each model
    shard locally selects the tokens routed to its own experts
    (capacity-bounded sort-gather), runs an MXU-shaped FFN per local
    expert, scatter-combines with the gate weights, and the standard
    row-parallel psum over `model` completes the combine.  Expert weights
    are FSDP-sharded on d_model over the dp group and all-gathered in bf16
    per layer (ZeRO-3).
    """
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.ctx import dp_axes_active, get_dist_mesh

    mesh = get_dist_mesh()
    dp = dp_axes_active()
    cd = cfg.compute_dtype
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    S = B * T
    n_dp_ = 1 if mesh is None else int(
        np.prod([mesh.shape[a] for a in dp]))
    if mesh is None or S % n_dp_ or (S // n_dp_) * K < E // 4:
        # tiny token counts (e.g. batch-1 decode): dense dispatch is cheap
        return _ffn_moe(cfg, lp, x)
    xs = x.reshape(S, D)

    # routing (computed in the replicated TP region; tiny)
    logits = (xs @ lp["router"].astype(cd)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    frac_t = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), 0)
    aux = E * jnp.sum(frac_t * jnp.mean(gates, axis=0))

    n_model = mesh.shape["model"]
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    E_loc = E // n_model
    S_loc = S // n_dp
    cap = max(int(np.ceil(S_loc * K * cfg.capacity_factor / E)), 8)

    def local_moe(xs_l, topi_l, topv_l, wi, wg, wo):
        # xs_l [S_loc, D]; wi/wg/wo already bf16 (cast OUTSIDE shard_map
        # so the cast can't be hoisted past the gather) -> gather full D
        wi = jax.lax.all_gather(wi, dp, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, dp, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, dp, axis=2, tiled=True)
        first = jax.lax.axis_index("model") * E_loc
        assign = topi_l.reshape(-1)              # [S_loc*K]
        gate = topv_l.reshape(-1)
        out = jnp.zeros((S_loc, D), jnp.float32)
        for el in range(E_loc):
            hit = assign == (first + el)
            order = jnp.argsort(~hit, stable=True)[:cap]
            valid = hit[order]
            tok = order // K
            g = jnp.where(valid, gate[order], 0.0)
            xe = xs_l[tok].astype(cd)
            h = (xe @ wi[el]) * jax.nn.silu(xe @ wg[el]) if cfg.gated_ffn \
                else jax.nn.gelu(xe @ wi[el])
            ye = (h @ wo[el]).astype(jnp.float32)
            out = out.at[tok].add(ye * g[:, None])
        # <=top_k nonzero contributions per token across shards: bf16
        # psum is numerically safe and halves the combine bytes
        return jax.lax.psum(out.astype(cd), "model")

    wi_spec = P("model", dp, None)
    wo_spec = P("model", None, dp)
    wi_b = wcast(lp["moe_wi"], cfg, *wi_spec)
    wg_b = wcast(lp["moe_wg"], cfg, *wi_spec) if cfg.gated_ffn else wi_b
    wo_b = wcast(lp["moe_wo"], cfg, *wo_spec)
    out = shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(dp, None), P(dp, None), P(dp, None),
                  wi_spec, wi_spec, wo_spec),
        out_specs=P(dp, None),
    )(xs, topi, topv, wi_b, wg_b, wo_b)
    return out.astype(cd).reshape(B, T, D), aux


def _attn(cfg: LMConfig, lp, x, positions, kv_cache=None, lengths=None):
    cd = cfg.compute_dtype
    B, T, D = x.shape
    H, K, dh = padded_heads(cfg), cfg.n_kv_heads, cfg.dh
    q = (x @ wcast(lp["wq"], cfg, "dp", "model")).reshape(B, T, H, dh)
    k = (x @ wcast(lp["wk"], cfg, "dp", "model")).reshape(B, T, K, dh)
    v = (x @ wcast(lp["wv"], cfg, "dp", "model")).reshape(B, T, K, dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["qnorm"])
        k = rms_norm(k, lp["knorm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if kv_cache is None:
        o = flash_attention_xla(ct_cast(q), ct_cast(k), ct_cast(v),
                                causal=True, chunk=cfg.attn_chunk)
        new_cache = None
    else:
        ck, cv = kv_cache                     # [B,Tmax,K,dh]
        idx = lengths[:, None] + jnp.arange(T)[None, :]       # [B,T]
        bidx = jnp.arange(B)[:, None]
        ck = ck.at[bidx, idx].set(k.astype(ck.dtype))
        cv = cv.at[bidx, idx].set(v.astype(cv.dtype))
        o = decode_attention(q, ck, cv, lengths + T)
        new_cache = (ck, cv)
    if H != cfg.n_heads:
        # zero the padded heads: exact published math, zero pad-gradients
        hmask = (jnp.arange(H) < cfg.n_heads).astype(o.dtype)
        o = o * hmask[None, None, :, None]
    o = o.reshape(B, T, H * dh).astype(cd)
    return o @ wcast(lp["wo"], cfg, "model", "dp"), new_cache


def _layer(cfg: LMConfig, lp, x, positions, kv_cache=None, lengths=None):
    x = ct_cast(x)  # pin the backward residual stream to compute dtype
    b1 = lp.get("ln1b")
    b2 = lp.get("ln2b")
    a, new_cache = _attn(cfg, lp, _norm(cfg, x, lp["ln1"], b1), positions,
                         kv_cache, lengths)
    x = x + a
    h = _norm(cfg, x, lp["ln2"], b2)
    aux = jnp.float32(0)
    if cfg.n_experts:
        moe = _ffn_moe_ep if cfg.moe_impl == "ep" else _ffn_moe
        f, aux = moe(cfg, lp, h)
        if cfg.dense_residual:
            f = f + _ffn_dense(cfg, lp, h)
    else:
        f = _ffn_dense(cfg, lp, h)
    return x + f, aux, new_cache


def lm_forward(cfg: LMConfig, params, tokens, positions=None):
    """tokens: [B, T] -> logits [B, T, vocab] (training/prefill, causal)."""
    cd = cfg.compute_dtype
    B, T = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    x = wcast(params["embed"], cfg, "model", None)[tokens]

    def body(carry, lp):
        x, aux = carry
        x2, a, _ = _layer(cfg, lp, x, positions)
        return (x2, aux + a), None

    step = body
    if cfg.remat:
        step = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0)), params["layers"])
    x = rms_norm(x, params["final_norm"])
    # logits [B, T, V] is the biggest tensor in the program: keep the time
    # axis sharded over `model` so no device ever holds [T, V] (the vocab
    # axis stays local -> softmax/CE need no collectives).
    from repro.dist.ctx import constrain, model_size
    if T % model_size() == 0:
        x = constrain(x, "dp", "model", None)
    # logits stay bf16: the [B, T/tp, V] tensor is the program's largest
    # temp; the loss does its reductions in f32 without materializing an
    # f32 copy (§Perf iter A5)
    logits = x @ wcast(params["unembed"], cfg, "dp", None)
    return logits, aux


def lm_loss(cfg: LMConfig, params, batch):
    """batch: dict(tokens [B,T], targets [B,T]).

    Cross-entropy from bf16 logits with f32 reductions: logsumexp and
    the target gather upcast per-element inside fused reductions, so no
    [B, T, V] f32 temp is ever materialized (§Perf iter A5).
    """
    logits, aux = lm_forward(cfg, params, batch["tokens"])
    tgt = jnp.take_along_axis(logits, batch["targets"][..., None],
                              -1)[..., 0].astype(jnp.float32)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    loss = (lse - tgt).mean()
    if cfg.n_experts:
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def lm_decode_step(cfg: LMConfig, params, tokens, kv_cache, lengths):
    """One serving step: tokens [B,1] + caches -> next-token logits.

    kv_cache: tuple of [L,B,Tmax,K,dh]; lengths: [B] current cache fill.
    """
    cd = cfg.compute_dtype
    B, T = tokens.shape
    positions = lengths[:, None] + jnp.arange(T)[None, :]
    x = wcast(params["embed"], cfg, "model", None)[tokens]

    def body(x, inp):
        lp, ck, cv = inp
        x2, _, (nk, nv) = _layer(cfg, lp, x, positions, (ck, cv), lengths)
        return x2, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["layers"], kv_cache[0], kv_cache[1]))
    x = rms_norm(x, params["final_norm"])
    logits = (x @ wcast(params["unembed"], cfg, "dp", None)
              ).astype(jnp.float32)
    return logits, (nk, nv)
