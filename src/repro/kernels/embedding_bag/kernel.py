"""Pallas TPU EmbeddingBag: scalar-prefetched row gather + bag reduce.

The bag indices are scalar-prefetched (SMEM) so the BlockSpec index_map
can stream exactly the needed table rows HBM->VMEM — the TPU version of
FBGEMM's TBE gather.  Grid (B, L): the L axis accumulates the bag sum in
the output block.  The huge table never leaves HBM except for the touched
rows (this is what makes the lookup the "work to data" hot path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _bag_kernel(idx_ref, row_ref, o_ref, *, L, combiner):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += row_ref[...].astype(o_ref.dtype)

    if combiner == "mean":
        @pl.when(l == L - 1)
        def _final():
            o_ref[...] = o_ref[...] / L


def embedding_bag_fwd(table, indices, *, combiner="sum", interpret=False):
    """table: [V, D]; indices: [B, L] int32 -> [B, D] (f32)."""
    V, D = table.shape
    B, L = indices.shape
    flat = indices.reshape(-1).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_bag_kernel, L=L, combiner=combiner),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, L),
            in_specs=[
                pl.BlockSpec((1, D), lambda b, l, idx: (idx[b * L + l], 0)),
            ],
            out_specs=pl.BlockSpec((1, D), lambda b, l, idx: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(flat, table)
    return out
