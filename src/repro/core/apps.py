"""Diffusion applications (the paper's `bfs-action`, plus future-work algs).

An app plugs into the generic ``OP_APP`` action.  All bundled apps follow a
*monotone relaxation* pattern so streaming updates never recompute from
scratch (the paper's central claim for dynamic BFS):

  relax(vals, incoming) -> (new_vals, changed)   # executed at the target
  edge_value(src_val, w)                          # value diffused along an edge
  propagate_on_insert(vals)                       # Listing 4 line 7 condition

``forward`` down the ghost chain always carries the slot's post-relax value
itself (same logical vertex, same value) — DESIGN §4.4.  The same property
makes the rhizome broadcast sound (DESIGN §4.5): an ``OP_RHIZOME_FWD``
carrying a canonical root's post-relax value is just another monotone
relax at each co-equal sibling root, so any interleaving of inserts,
broadcasts and link-acks converges to the same fixpoint, and the host
readback can ``combine`` (min) over the roots at any instant.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# python float (not a jnp scalar) so app lambdas that close over it embed
# it as a literal — required for tracing inside the Pallas cycle megakernel
INF = 1e9


@dataclasses.dataclass(frozen=True)
class DiffusionApp:
    name: str
    # (vals[VN], incoming scalar) -> (new vals[VN], changed bool)
    relax: Callable
    # (emit source value scalar, edge weight scalar) -> scalar
    edge_value: Callable
    # vals[VN] -> bool : propagate on edge-insert? (Listing 4, line 7)
    propagate_on_insert: Callable
    # neutral element of relax ("unreached"): relax(v, init_val) must be a
    # no-op.  A tuple gives per-query init values (qbatch > 1 composites —
    # tuples, not arrays, keep the app hashable for the jit static args)
    init_val: float | tuple = 1e9
    n_vals: int = 1
    # host-side merge of one vertex's values across its rhizome roots;
    # must agree with relax's fixpoint direction (min for the bundled apps)
    combine: Callable = np.minimum
    # coalescing rule of the deferred app-forward register (DESIGN §4.4):
    # merges queued forwards onto a pending future; must be relax's meet
    # (min for min-monotone apps, max for the maximin widest-path app)
    fwd_merge: Callable = jnp.minimum
    # neutral element of fwd_merge (loses every merge); per-query tuple ok
    fwd_neutral: float | tuple = 1e9
    # query-batch width (repro.mq, DESIGN §10): > 1 marks a composite app
    # whose relax/edge_value act on the whole [..., qbatch] value vector
    qbatch: int = 1
    # the per-slot scalar apps of a qbatch > 1 composite (else empty)
    slot_apps: tuple = ()


def neutral_vec(vals):
    """A [Q] constant vector assembled from scalar literals only.

    ``jnp.asarray(tuple)`` would embed a float32[Q] constant in the
    jaxpr, which the Pallas cycle megakernel rejects (kernels may not
    capture array constants).  Building it as iota + unrolled scalar
    selects keeps every constant a literal, so the same cycle_body
    traces on both backends.  Scalar inputs pass through unchanged.
    """
    if not isinstance(vals, tuple):
        return jnp.float32(vals)
    idx = jax.lax.iota(jnp.int32, len(vals))
    out = jnp.zeros((len(vals),), jnp.float32)
    for q, v in enumerate(vals):
        out = jnp.where(idx == q, jnp.float32(v), out)
    return out


def _min_relax(vals, incoming):
    new0 = jnp.minimum(vals[..., 0], incoming)
    changed = incoming < vals[..., 0]
    return vals.at[..., 0].set(new0), changed


def _max_relax(vals, incoming):
    new0 = jnp.maximum(vals[..., 0], incoming)
    changed = incoming > vals[..., 0]
    return vals.at[..., 0].set(new0), changed


BFS = DiffusionApp(
    name="bfs",
    relax=_min_relax,
    edge_value=lambda v, w: v + 1.0,
    propagate_on_insert=lambda vals: vals[..., 0] < INF,
)

SSSP = DiffusionApp(
    name="sssp",
    relax=_min_relax,
    edge_value=lambda v, w: v + w,
    propagate_on_insert=lambda vals: vals[..., 0] < INF,
)

# Connected components by min-label propagation (undirected streams).
CC = DiffusionApp(
    name="cc",
    relax=_min_relax,
    edge_value=lambda v, w: v,
    propagate_on_insert=lambda vals: vals[..., 0] < INF,
)

# Ingestion-only mode: the paper's separate experiment with bfs-action
# propagation disabled (§5) to isolate streaming-insert time.
INGEST_ONLY = DiffusionApp(
    name="ingest_only",
    relax=lambda vals, incoming: (vals, jnp.zeros(vals.shape[:-1], bool)),
    edge_value=lambda v, w: v,
    propagate_on_insert=lambda vals: jnp.zeros(vals.shape[:-1], bool),
)

# Widest path (maximin bottleneck capacity): the first max-monotone app —
# relax keeps the LARGEST bottleneck seen, an edge caps the path at
# min(path, w), sources seed +INF.  Proves the frame generalizes across
# fixpoint directions (Besta et al. taxonomy): every knob that hard-coded
# "min" (host combine, forward-register merge, neutral elements) flips.
# Idempotent like the min trio, so ghost-chain forwards and rhizome
# broadcasts of post-relax value snapshots stay sound (unlike sum/count
# relaxes — k-core / delta-PageRank need a residual protocol, DESIGN §10).
WIDEST = DiffusionApp(
    name="widest",
    relax=_max_relax,
    edge_value=lambda v, w: jnp.minimum(v, w),
    propagate_on_insert=lambda vals: vals[..., 0] > 0.0,
    init_val=0.0,
    combine=np.maximum,
    fwd_merge=jnp.maximum,
    fwd_neutral=0.0,
)

# Most-reliable path (max-product of edge reliabilities in (0, 1]):
# max-monotone like WIDEST but multiplicative along edges.
RELIABLE = DiffusionApp(
    name="reliable",
    relax=_max_relax,
    edge_value=lambda v, w: v * w,
    propagate_on_insert=lambda vals: vals[..., 0] > 0.0,
    init_val=0.0,
    combine=np.maximum,
    fwd_merge=jnp.maximum,
    fwd_neutral=0.0,
)

APPS = {a.name: a for a in (BFS, SSSP, CC, INGEST_ONLY, WIDEST, RELIABLE)}
