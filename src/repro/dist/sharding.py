"""GSPMD sharding rules for every workload family (DESIGN §5).

The headline map is :func:`cca_state_shardings`: the decentralized
engine's whole machine state — one fixed-shape pytree of ``[H, W, ...]``
cell-major arrays — is laid onto the (data, model) device mesh by
sharding cell ROWS over the data-parallel group and cell COLUMNS over the
model axis.  Each device then owns a contiguous tile of compute cells
(their slots, queues, channels and LCO futures travel with them); the
engine code itself stays single-abstraction — ``run_chunk_body`` is
unchanged, and the mesh hops / quiescence sums lower to
collective-permutes / all-reduces between tiles.  Per-leaf rule:

* rank >= 2 with both leading dims divisible -> ``P(dp, "model", ...)``
  (the [H, W] cell grid, tiled),
* rank >= 1 with the leading dim divisible   -> ``P(dp, ...)``
  (the [IO, ...] streaming-ingestion leaves, row-sharded),
* everything else (cycle/stat scalars)       -> replicated.

The LM / GNN / DLRM families below feed ``launch/steps.py``; every rule
degrades per-dimension to replicated when an axis is missing from the
mesh or does not divide (ctx.resolve_spec), so the same code drives the
16x16 production pod and a 1-device CPU test.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.ctx import dp_axes_active, model_size, resolve_spec


def pad_to(n: int, mult: int) -> int:
    """Round ``n`` up to a multiple of ``mult`` (mult <= 1 -> n)."""
    if mult <= 1:
        return int(n)
    return int(-(-int(n) // int(mult)) * int(mult))


def _dp_entry(mesh):
    """The data-parallel axis group as a PartitionSpec entry."""
    dp = dp_axes_active(mesh)
    return dp[0] if len(dp) == 1 else tuple(dp)


def _dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes_active(mesh)
                        if a in mesh.axis_names]))


def _ns(mesh, shape, axes) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(mesh, shape, axes))


# ------------------------------------------------------------------ CCA ---

def cca_state_shardings(mesh, state_shape):
    """Per-leaf shardings for the engine's MachineState pytree.

    ``state_shape`` is the abstract state (``jax.eval_shape`` of
    ``init_state``); returns the same pytree with a NamedSharding per
    leaf, suitable for ``jax.jit(in_shardings=...)`` / ``device_put``.
    """
    dp_n = _dp_size(mesh)
    tp_n = model_size(mesh)

    def leaf(l):
        shape = l.shape
        spec = [None] * len(shape)
        if len(shape) >= 2 and shape[0] % dp_n == 0 and shape[1] % tp_n == 0:
            spec[0], spec[1] = "dp", "model"
        elif len(shape) >= 1 and shape[0] and shape[0] % dp_n == 0:
            spec[0] = "dp"
        return _ns(mesh, shape, spec)

    return jax.tree.map(leaf, state_shape)


# ------------------------------------------------------------------- LM ---

# Per-layer stacked weights: logical axes of the TRAILING dims (the
# leading L layer-stack dim is always replicated — lax.scan slices it).
# Mirrors the wcast/constrain calls in models/transformer.py.
_LM_LAYER_AXES = {
    "wq": ("dp", "model"), "wk": ("dp", "model"), "wv": ("dp", "model"),
    "wo": ("model", "dp"),
    "ffn_wi": ("dp", "model"), "ffn_wg": ("dp", "model"),
    "ffn_wo": ("model", "dp"),
    "moe_wi": ("model", "dp", None), "moe_wg": ("model", "dp", None),
    "moe_wo": ("model", None, "dp"),
    "router": ("dp", None),
}


def lm_param_shardings(mesh, params_shape):
    """FSDP (d_model over dp) x TP (heads/ffn/experts over model)."""
    layers = {
        k: _ns(mesh, v.shape,
               (None, *_LM_LAYER_AXES.get(k, (None,) * (v.ndim - 1))))
        for k, v in params_shape["layers"].items()
    }
    return dict(
        embed=_ns(mesh, params_shape["embed"].shape, ("model", None)),
        unembed=_ns(mesh, params_shape["unembed"].shape, ("dp", None)),
        final_norm=_ns(mesh, params_shape["final_norm"].shape, (None,)),
        layers=layers,
    )


def lm_batch_shardings(mesh):
    dp = _dp_entry(mesh)
    ns = NamedSharding(mesh, P(dp, None))
    return dict(tokens=ns, targets=ns)


def lm_cache_shardings(mesh, cfg, batch: int):
    """KV cache (k, v) of [L, B, Tmax, K, dh]: batch over dp; KV heads
    over model when they divide, else the time axis (flash-decoding)."""
    dp = _dp_entry(mesh)
    bspec = dp if batch > 1 and batch % _dp_size(mesh) == 0 else None
    if cfg.n_kv_heads % model_size(mesh) == 0:
        spec = P(None, bspec, None, "model", None)
    else:
        spec = P(None, bspec, "model", None, None)
    ns = NamedSharding(mesh, spec)
    return (ns, ns)


# ------------------------------------------------------------------ GNN ---

def gnn_axes(mesh) -> tuple:
    """Axes the node/edge dimension shards over (graph models flatten the
    whole mesh into one big 'graph' axis group)."""
    return tuple(a for a in ("data", "model") if a in mesh.axis_names)


def gnn_param_shardings(mesh, params_shape):
    """GNN weights are tiny relative to the graph: fully replicated."""
    return jax.tree.map(lambda l: NamedSharding(mesh, P()), params_shape)


def gnn_graph_shardings(mesh, fields: dict) -> dict:
    """Shardings for the non-None Graph fields: node features row-sharded,
    every ``*edge_index`` sharded along the edge axis (owner-partitioned
    buckets line up with the node blocks — graph/partition.py)."""
    ax = gnn_axes(mesh)
    ax = ax[0] if len(ax) == 1 else ax
    out = {}
    for k, v in fields.items():
        if v is None:
            continue
        if k.endswith("edge_index"):
            out[k] = NamedSharding(mesh, P(None, ax))
        else:  # x [N, D] / e [E, De]
            out[k] = NamedSharding(mesh, P(ax, None))
    return out


# ----------------------------------------------------------------- DLRM ---

def dlrm_param_shardings(mesh, params_shape):
    """Embedding tables row-sharded over 'model' (lookups route to the
    owning shard — "send work to data"); MLPs replicated."""
    tp = model_size(mesh)
    tables = [
        _ns(mesh, t.shape, ("model", None)) if t.shape[0] % tp == 0
        else NamedSharding(mesh, P())
        for t in params_shape["tables"]
    ]
    rep = jax.tree.map(lambda l: NamedSharding(mesh, P()),
                       dict(bot=params_shape["bot"],
                            top=params_shape["top"]))
    return dict(tables=tables, bot=rep["bot"], top=rep["top"])


def dlrm_batch_shardings(mesh, with_candidates: bool = False):
    dp = _dp_entry(mesh)
    out = dict(dense=NamedSharding(mesh, P(dp, None)),
               sparse=NamedSharding(mesh, P(dp, None, None)),
               labels=NamedSharding(mesh, P(dp)))
    if with_candidates:
        # candidate rows spread over 'model': the query is replicated
        # there, so scoring is local and top-k merges shard maxima
        out["candidates"] = NamedSharding(mesh, P("model", None))
    return out
