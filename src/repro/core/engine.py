"""The cycle engine: composes routing, execution and ingestion into one
pure ``state -> state`` step, runs it to quiescence, and exposes the
streaming-increment API used by the experiments.

Cycle order (all fixed-shape, fully vectorized over the cell grid):

  1. hop_stage      channel heads advance one link (YX DOR, backpressure)
  2. staging        active actions stage one ``propagate`` message
  3. phase0         idle cells pop one action and run its compute step
  4. io_stage       IO cells inject the next streamed edge

Quiescence (the paper's Terminator object): no queued actions, no channel
occupancy, no active action, no deferred future tasks, no pending IO.
On a real pod this is a tree all-reduce of the pending counters; here it is
literally ``jnp.sum`` inside the jitted step — GSPMD lowers it to
``all-reduce`` when the grid is sharded (see the dry-run HLO).

Two execution backends share ``cycle_body`` (DESIGN §6):

  * ``backend="jnp"`` — lax chunk runners over the HBM-resident state;
  * ``backend="pallas"`` — the fused cycle megakernel
    (``kernels/cca_cycle``): K cycles per launch with the state leaves
    held in VMEM, ``interpret=True`` fallback off-TPU.

The streaming driver's default fast path (``collect_traces=False``) runs
the whole chunk loop of an increment — including the livelock detector —
as one device-side ``lax.while_loop`` per spill pass: exactly one jit
call and one scalar readback per pass.  Per-cycle activity traces are
opt-in (``collect_traces=True``) and use the chunked host loop.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alloc import rhizome_rcs
from repro.core.apps import APPS, DiffusionApp
from repro.core.config import EngineConfig
from repro.core.exec_stage import phase0_stage, staging_stage
from repro.core.ingest import io_stage, load_stream
from repro.core.routing import hop_stage, park_stage
from repro.core.state import (TM_HOP, TM_HW_AQ, TM_L_OCC, MachineState,
                              init_state, root_addr, self_cell_grid)
from repro.obs import frames as obs_frames


class CycleStats(NamedTuple):
    active: jax.Array      # cells doing compute/staging work this cycle
    in_flight: jax.Array   # messages sitting in channels
    backlog: jax.Array     # queued actions
    hops: jax.Array        # link traversals this cycle
    quiescent: jax.Array   # bool


def _rc(cfg: EngineConfig):
    rows = jnp.arange(cfg.height, dtype=jnp.int32)[:, None]
    cols = jnp.arange(cfg.width, dtype=jnp.int32)[None, :]
    return (jnp.broadcast_to(rows, (cfg.height, cfg.width)),
            jnp.broadcast_to(cols, (cfg.height, cfg.width)))


def quiescent(st: MachineState) -> jax.Array:
    return ((jnp.sum(st.aq_n) == 0) & (jnp.sum(st.ch_n) == 0)
            & (jnp.sum(st.pk_n) == 0)
            & ~jnp.any(st.cvalid) & (jnp.sum(st.fq_n) == 0)
            & ~jnp.any(st.fwd_pending)
            & (jnp.sum(st.io_n - st.io_pos) == 0))


def cycle_body(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    """One machine cycle, no stats reductions: hop -> staging -> phase0 ->
    io.  The single copy of the cycle semantics, shared verbatim by the
    jnp chunk runners below and the Pallas cycle megakernel
    (``kernels/cca_cycle``).  Returns the per-cell activity masks as aux
    so ``cycle_step`` can build :class:`CycleStats` without recompute
    (callers that ignore them pay nothing — XLA DCEs the masks)."""
    rows, cols = _rc(cfg)
    busy0 = st.cvalid
    if cfg.telemetry:
        # per-lane occupancy integral at cycle entry (avg depth =
        # TM_L_OCC / cycles); the other planes accumulate inside the
        # stages where the grant/stall masks live (DESIGN §8)
        st = st._replace(tm_lane=st.tm_lane.at[..., TM_L_OCC].add(st.ch_n))
    st, hops = hop_stage(cfg, st, rows, cols)
    if cfg.lanes > 1:
        # re-inject parked transit messages right after the hop stage,
        # while freshly-vacated lane slots are still free (DESIGN §7);
        # with lanes == 1 nothing ever parks — skip for a bit-exact trace
        st = park_stage(cfg, st, rows, cols)
    st, active_a = staging_stage(cfg, app, st, rows, cols)
    st, popped = phase0_stage(cfg, app, st, rows, cols, busy0)
    st = io_stage(cfg, st, rows, cols)
    if cfg.telemetry:
        hw = jnp.stack([st.aq_n, st.pk_n], axis=-1)
        st = st._replace(tm_hiw=jnp.maximum(st.tm_hiw, hw))
    st = st._replace(cycle=st.cycle + 1,
                     stat_hops=st.stat_hops + hops)
    return st, (active_a, popped, hops)


def cycle_step(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    st, (active_a, popped, hops) = cycle_body(cfg, app, st)
    stats = CycleStats(
        active=jnp.sum((active_a | popped).astype(jnp.int32)),
        in_flight=jnp.sum(st.ch_n) + jnp.sum(st.pk_n),
        backlog=jnp.sum(st.aq_n),
        hops=hops, quiescent=quiescent(st))
    return st, stats


def run_chunk_body(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    """Un-jitted fixed-length chunk (dry-run / roofline entry point: the
    caller jits this with the production-mesh shardings)."""
    def body(s, _):
        s2, _ = cycle_body(cfg, app, s)
        return s2, None
    st, _ = jax.lax.scan(body, st, None, length=cfg.chunk)
    return st


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=2)
def run_chunk(cfg: EngineConfig, app: DiffusionApp, st: MachineState):
    """Scan `cfg.chunk` cycles; freeze once quiescent (identity cycles).

    The stacked ``stats.quiescent`` records quiescence at cycle ENTRY
    (i.e. flags the frozen identity cycles), so ``argmax`` over it is
    exactly the number of cycles executed this chunk — in agreement with
    the state's own ``cycle`` counter and the sync-free device loop.
    """
    def body(s, _):
        done = quiescent(s)
        s2, stats = cycle_step(cfg, app, s)
        s = jax.tree.map(lambda a, b: jnp.where(done, a, b), s, s2)
        return s, stats._replace(quiescent=done)
    return jax.lax.scan(body, st, None, length=cfg.chunk)


def run_to_quiescence_while(cfg: EngineConfig, app: DiffusionApp,
                            st: MachineState, max_cycles=None):
    """Pure lax.while_loop runner (no traces) — the dry-run/roofline path."""
    mc = jnp.int32(max_cycles or cfg.max_cycles)
    start = st.cycle

    def cond(s):
        return (~quiescent(s)) & (s.cycle - start < mc)

    def body(s):
        s2, _ = cycle_body(cfg, app, s)
        return s2

    return jax.lax.while_loop(cond, body, st)


# Livelock detection granularity: this many consecutive chunks with zero
# executed actions while work is pending => message-dependent deadlock
# (DESIGN §4.2).  Shared by the device-side fast path and the host-side
# trace path so both backends fail identically.
LIVELOCK_CHUNKS = 8


def _livelock_msg(cfg: EngineConfig) -> str:
    return ("engine livelock: no action executed and no message hopped "
            f"for {LIVELOCK_CHUNKS * cfg.chunk} cycles with work pending "
            "— every virtual lane is stuck. "
            f"Enable virtual lanes (lanes>=2, currently {cfg.lanes}) so "
            "protocol traffic escapes head-of-line blocking, and/or "
            "increase chan_cap (>=4) / queue_cap "
            f"(>= aq_reserve+sys_reserve+8 = "
            f"{cfg.aq_reserve + cfg.sys_reserve + 8}) — see "
            "DESIGN.md §4.2/§7 buffer-sizing rules.")


class LivelockError(RuntimeError):
    """Message-dependent deadlock detected (DESIGN §4.2).

    Structured replacement for the bare ``RuntimeError`` string: carries
    the machine ``cycle`` at detection, the ``chunk`` index within the
    increment, and — when ``cfg.telemetry`` is on — the flight-recorder
    ``frames`` (:class:`repro.obs.FrameLog`; ``None`` otherwise).
    Subclasses ``RuntimeError`` with "livelock" in the message, so
    pre-existing ``except RuntimeError`` + substring handlers keep
    working without regex-parsing the message.
    """

    def __init__(self, msg: str, *, cycle: int, chunk: int, frames=None):
        super().__init__(msg)
        self.cycle = cycle
        self.chunk = chunk
        self.frames = frames


def _raise_livelock(cfg: EngineConfig, *, cycle: int, chunk: int,
                    frames=None):
    """Build and raise :class:`LivelockError`, appending the flight
    recorder's wedge report when frames were captured."""
    msg = _livelock_msg(cfg)
    if frames is not None and len(frames) >= 2:
        from repro.obs.flight import render_wedge_report
        msg = msg + "\n" + render_wedge_report(cfg, frames)
    raise LivelockError(msg, cycle=cycle, chunk=chunk, frames=frames)


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=2)
def _increment_device_loop(cfg: EngineConfig, app: DiffusionApp,
                           st: MachineState, limit):
    """One increment pass entirely on device: a ``lax.while_loop`` over
    chunks with the livelock detector folded in as a no-progress counter.

    Host<->device traffic per pass is exactly one donated state in and a
    handful of scalars out — no per-chunk ``int(stat_exec)`` syncs, no
    per-cycle stats transfer.  Each chunk either runs
    :func:`run_to_quiescence_while` capped at ``cfg.chunk`` cycles
    (backend="jnp") or one fused Pallas megakernel launch of
    ``cfg.chunk`` cycles (backend="pallas"); both leave the state frozen
    at the exact quiescence cycle, so the two backends are bit-exact.
    """
    start = st.cycle

    if cfg.backend == "pallas":
        from repro.kernels.cca_cycle.ops import cca_cycle_chunk

        def chunk(s):
            return cca_cycle_chunk(cfg, app, s)[0]
    else:
        def chunk(s):
            return run_to_quiescence_while(cfg, app, s,
                                           max_cycles=cfg.chunk)

    def cond(carry):
        s, _, noprog, _ = carry
        return ((~quiescent(s)) & (s.cycle - start < limit)
                & (noprog < LIVELOCK_CHUNKS))

    def body(carry):
        s, last_prog, noprog, ring = carry
        s = chunk(s)
        # progress = an action completed OR a message hopped a link: with
        # virtual lanes a chunk may be all-transit (messages draining
        # through sibling lanes while a hub lane is full), so exec-only
        # progress would false-positive; no-progress now means every
        # lane AND every cell is stuck (DESIGN §7)
        prog = s.stat_exec + s.stat_hops
        noprog = jnp.where(prog == last_prog, noprog + 1, jnp.int32(0))
        if cfg.telemetry:
            ring = obs_frames.ring_store(ring, obs_frames.snapshot(cfg, s))
        return (s, prog, noprog, ring)

    if cfg.telemetry:
        # frame 0 = pass baseline (also guarantees a non-empty ring even
        # for an increment that is quiescent on entry)
        ring0 = obs_frames.ring_store(obs_frames.init_ring(cfg),
                                      obs_frames.snapshot(cfg, st))
    else:
        ring0 = None  # empty pytree: rides the carry at zero cost
    st, _, noprog, ring = jax.lax.while_loop(
        cond, body, (st, st.stat_exec + st.stat_hops, jnp.int32(0), ring0))
    return st, (st.cycle - start, quiescent(st), noprog, st.stat_hops,
                st.stat_exec, st.stat_stall, st.stat_allocs), ring


@dataclasses.dataclass
class IncrementResult:
    cycles: int
    active_per_cycle: np.ndarray
    in_flight_per_cycle: np.ndarray
    hops: int
    execs: int
    stalls: int
    allocs: int
    # telemetry frame log (``cfg.telemetry=True`` only, else None): the
    # last ``cfg.frame_ring`` per-chunk frames of each spill pass, read
    # back as one batched transfer per pass (DESIGN §8)
    frames: "obs_frames.FrameLog | None" = None


class StreamingEngine:
    """Host-side driver: the accelerator-style main() of paper Listing 1."""

    def __init__(self, cfg: EngineConfig, app: str | DiffusionApp = "bfs"):
        self.cfg = cfg
        self.app = APPS[app] if isinstance(app, str) else app
        cfg = dataclasses.replace(cfg, n_vals=self.app.n_vals,
                                  qbatch=self.app.qbatch)
        self.cfg = cfg
        self.state = init_state(cfg, init_vals=self.app.init_val,
                                fwd_init=self.app.fwd_neutral)
        self.total_cycles = 0
        self.totals = dict(hops=0, execs=0, stalls=0, allocs=0)
        # resilience bookkeeping (DESIGN §9)
        self.stream_pos = 0        # increments completed == checkpoint step
        self.recovery_log = []     # one dict per livelock recovery attempt
        self._ingest_budget = None  # tm_hiw-gated admission limit

    # -- seeding (e.g. the BFS source vertex gets level 0 pre-stream) --
    def seed(self, vid: int, value: float, val_idx: int = 0):
        """Host-write a value into EVERY rhizome root of ``vid`` so the
        co-equal roots start value-synced (DESIGN §4.5)."""
        cfg = self.cfg
        ks = np.arange(cfg.rhizome_cap)
        r, c, s = rhizome_rcs(cfg, vid, ks)      # [R] each: one scatter
        self.state = self.state._replace(
            vals=self.state.vals.at[r, c, s, val_idx].set(value))

    # -- stream one increment of edges and run to quiescence --
    def run_increment(self, edges: np.ndarray,
                      max_cycles: int | None = None,
                      collect_traces: bool = False,
                      recover=None, ckpt=None,
                      ckpt_block: bool = False) -> IncrementResult:
        """Ingest ``edges`` and run to quiescence.

        ``collect_traces=False`` (default) is the sync-free fast path:
        the whole chunk loop — including the §4.2 livelock detector —
        runs device-side in one jit call per spill pass, and only scalar
        totals come back (``active_per_cycle``/``in_flight_per_cycle``
        are empty).  ``collect_traces=True`` uses the chunked host loop
        and returns the full per-cycle activity traces (jnp chunk
        runner; identical state/totals either way).

        Resilience knobs (DESIGN §9) — both default off, and the
        defaults leave the run bit-identical to the pre-resilience
        driver:

        * ``ckpt`` — a ``train.checkpoint.Checkpointer``: publish a
          durable boundary checkpoint (step = ``stream_pos``) BEFORE
          ingesting this increment.  Default is async, so serialization
          overlaps the device loop below; ``ckpt_block=True`` publishes
          synchronously.  A crash mid-increment restores the boundary
          and replays this increment bit-exactly.
        * ``recover`` — a ``resilience.RecoveryPolicy``: on
          :class:`LivelockError`, roll back to the boundary snapshot,
          escalate lanes/queue_cap per the policy, back off
          exponentially, and retry the increment.  Every attempt is
          appended to ``self.recovery_log`` (with the flight-recorder
          wedge report when telemetry is on); once the budget is spent
          the error re-raises with the attempt log in the message.
          A successful escalation keeps the relieved config for the
          rest of the stream (graceful degradation, not a rollback).
        """
        if ckpt is not None:
            self.checkpoint(ckpt, block=ckpt_block)
        if recover is None:
            res = self._run_increment(edges, max_cycles, collect_traces)
            self.stream_pos += 1
            return res
        from repro.resilience.recover import migrate_state
        base_cfg = self.cfg
        # the boundary snapshot IS the recovery point: quiescent, so
        # migrate_state can re-seat it under an escalated config
        snapshot = jax.device_get(self.state)
        for attempt in range(recover.max_attempts + 1):
            try:
                res = self._run_increment(edges, max_cycles, collect_traces)
                self.stream_pos += 1
                return res
            except LivelockError as e:
                entry = dict(attempt=attempt, cycle=e.cycle, chunk=e.chunk,
                             lanes=self.cfg.lanes,
                             queue_cap=self.cfg.queue_cap,
                             wedge=str(e))
                self.recovery_log.append(entry)
                if attempt >= recover.max_attempts:
                    log = "\n".join(
                        f"  attempt {n['attempt']}: lanes={n['lanes']} "
                        f"queue_cap={n['queue_cap']} wedged at cycle "
                        f"{n['cycle']}" for n in self.recovery_log)
                    raise LivelockError(
                        f"{e}\nrecovery budget exhausted "
                        f"({recover.max_attempts} escalations):\n{log}",
                        cycle=e.cycle, chunk=e.chunk,
                        frames=e.frames) from e
                new_cfg = recover.escalate(base_cfg, attempt + 1)
                delay = recover.backoff_s * (2 ** attempt)
                entry["backoff_s"] = delay
                entry["escalated_to"] = dict(lanes=new_cfg.lanes,
                                             queue_cap=new_cfg.queue_cap)
                if delay:
                    time.sleep(delay)
                self.cfg = new_cfg
                self.state = migrate_state(new_cfg, self.app, snapshot)
                self._ingest_budget = None  # re-learn under the new sizing

    def _run_increment(self, edges, max_cycles, collect_traces):
        cfg = self.cfg
        limit = max_cycles or cfg.max_cycles
        self.state, spill = load_stream(cfg, self.state, edges,
                                        limit=self._ingest_limit())
        self.state = self.state._replace(stat_hops=jnp.int32(0),
                                         stat_exec=jnp.int32(0),
                                         stat_stall=jnp.int32(0),
                                         stat_allocs=jnp.int32(0))
        if cfg.qbatch > 1:
            # per-query relax counters reset per increment so the mq
            # session layer reads them as this-increment activity (§10);
            # qlast persists — it is the absolute settle cycle per slot
            self.state = self.state._replace(
                qchg=jnp.zeros_like(self.state.qchg))
        if cfg.faults is not None:
            # fault counters reset with the stat_* scalars: the §9 loss
            # detector reconciles per increment
            self.state = self.state._replace(
                flt=jnp.zeros_like(self.state.flt))
        if cfg.telemetry:
            # the telemetry planes reset with the stat_* scalars so the
            # final frame of the increment reconciles exactly (DESIGN §8)
            self.state = self.state._replace(
                tm_cell=jnp.zeros_like(self.state.tm_cell),
                tm_lane=jnp.zeros_like(self.state.tm_lane),
                tm_hiw=jnp.zeros_like(self.state.tm_hiw))
        if collect_traces:
            return self._run_increment_traced(spill, limit)
        rings = []
        cycles, q, noprog, counters, spill = self._device_passes(
            cfg, spill, limit, rings)
        frames = obs_frames.FrameLog.from_rings(rings) if rings else None
        if not q and noprog >= LIVELOCK_CHUNKS:
            # Message-dependent-deadlock detector: YX DOR keeps the
            # NETWORK acyclic, but the execute stage (pop -> emit ->
            # channel) can close a protocol cycle when buffers are sized
            # below the workload's dependency depth.  Fail loudly with
            # sizing advice — and the flight recorder's wedge report when
            # telemetry is on — instead of silently dropping work.
            _raise_livelock(cfg, cycle=cycles, chunk=cycles // cfg.chunk,
                            frames=frames)
        if len(spill):
            raise RuntimeError(self._spill_msg(limit, spill))
        if cfg.faults is not None:
            cycles = self._repair_rounds(limit, cycles, rings)
            counters = tuple(int(x) for x in jax.device_get((
                self.state.stat_hops, self.state.stat_exec,
                self.state.stat_stall, self.state.stat_allocs)))
            frames = (obs_frames.FrameLog.from_rings(rings)
                      if rings else None)
        if cfg.ingest_guard:
            # learn the admission budget for the NEXT increment from this
            # increment's action-queue hi-water marks
            self._update_ingest_budget()
        return self._finish_increment(
            cycles, *counters,
            np.zeros(0, np.int32), np.zeros(0, np.int32), frames)

    def _device_passes(self, cfg, spill, limit, rings, cycles=0):
        """Sync-free device passes until quiescence with the spill fully
        drained, or until the cycle/livelock budget trips.  Returns
        ``(cycles, quiescent, noprog, (hops, execs, stalls, allocs),
        spill)`` — counters are the increment-cumulative stat scalars."""
        while True:
            self.state, out, ring = _increment_device_loop(
                cfg, self.app, self.state, limit - cycles)
            # exactly ONE batched transfer per pass: the scalar record
            # and the frame ring come back together
            out, ring = jax.device_get((out, ring))
            ran, q, noprog, hops, execs, stalls, allocs = \
                (int(x) for x in out)
            if ring is not None:
                rings.append(ring)
            cycles += ran
            if q and len(spill):
                # io_stream_cap overflow residue: the loaded prefix is
                # fully consumed at quiescence, so the next pass has the
                # whole IO capacity again (DESIGN §4.2)
                if cfg.ingest_guard:
                    self._update_ingest_budget()
                self.state, spill = load_stream(cfg, self.state, spill,
                                                limit=self._ingest_limit())
                continue
            break
        return cycles, q, noprog, (hops, execs, stalls, allocs), spill

    def _run_increment_traced(self, spill, limit) -> IncrementResult:
        """Chunked host loop with per-cycle activity traces (the original
        driver); used when ``collect_traces=True``."""
        cfg = self.cfg
        act, flt = [], []
        cycles = 0
        last_exec, no_progress = 0, 0
        ring = None
        if cfg.telemetry:
            # same frame schema as the device loop, snapshotted eagerly
            # per chunk (this is the debug path — syncs are fine here)
            ring = obs_frames.ring_store(obs_frames.init_ring(cfg),
                                         obs_frames.snapshot(cfg, self.state))
        while cycles < limit:
            self.state, stats = run_chunk(cfg, self.app, self.state)
            if cfg.telemetry:
                ring = obs_frames.ring_store(
                    ring, obs_frames.snapshot(cfg, self.state))
            q = np.asarray(stats.quiescent)
            a = np.asarray(stats.active)
            f = np.asarray(stats.in_flight)
            if q.any():
                n = int(np.argmax(q))  # first quiescent cycle in chunk
                act.append(a[:n]); flt.append(f[:n])
                cycles += n
                if len(spill):
                    self.state, spill = load_stream(cfg, self.state, spill)
                    continue
                break
            act.append(a); flt.append(f)
            cycles += cfg.chunk
            e = int(self.state.stat_exec) + int(self.state.stat_hops)
            no_progress = no_progress + 1 if e == last_exec else 0
            last_exec = e
            if no_progress >= LIVELOCK_CHUNKS:
                frames = (obs_frames.FrameLog.from_rings(
                    [jax.device_get(ring)]) if ring is not None else None)
                _raise_livelock(cfg, cycle=cycles,
                                chunk=cycles // cfg.chunk, frames=frames)
        if len(spill):
            raise RuntimeError(self._spill_msg(limit, spill))
        if cfg.faults is not None:
            # debug path reuses the device-loop repair passes (per-cycle
            # traces cover the faulty run; the repair tail is untraced)
            cycles = self._repair_rounds(limit, cycles, [])
        if cfg.ingest_guard:
            self._update_ingest_budget()
        frames = (obs_frames.FrameLog.from_rings([jax.device_get(ring)])
                  if ring is not None else None)
        return self._finish_increment(
            cycles, int(self.state.stat_hops), int(self.state.stat_exec),
            int(self.state.stat_stall), int(self.state.stat_allocs),
            np.concatenate(act) if act else np.zeros(0, np.int32),
            np.concatenate(flt) if flt else np.zeros(0, np.int32), frames)

    # -- detection + repair: the §8 invariants as a loss detector (§9) --

    def _loss_count(self) -> int:
        """Messages lost this increment: the injected-fault counters,
        cross-checked (when telemetry is on) against the §8 conservation
        invariant — link departures (``stat_hops``) minus link deliveries
        (sum of the ``TM_HOP`` plane) is exactly the drop count, with no
        reference to the injection bookkeeping."""
        from repro.resilience.faults import FLT_CORRUPT, FLT_DROP
        flt = np.asarray(jax.device_get(self.state.flt))
        lost = int(flt[FLT_DROP]) + int(flt[FLT_CORRUPT])
        if self.cfg.telemetry:
            gap = int(self.state.stat_hops) - int(
                np.asarray(self.state.tm_cell)[..., TM_HOP].sum())
            lost = max(lost, gap + int(flt[FLT_CORRUPT]))
        return lost

    def _repair_entries(self) -> np.ndarray:
        """Stream rows re-injecting every finite durable value at every
        active rhizome root of its vertex: ``(vid, -(k+1), value_bits)``
        sentinel rows (negative dst => OP_REPAIR, see io_stage).  The
        forced re-diffusion of all of them, run to quiescence over the
        intact edge storage, is one full monotone relaxation sweep from
        correct sources — it reaches the exact fixpoint in a single
        fault-free round (DESIGN §9)."""
        cfg, app = self.cfg, self.app
        vids = np.arange(cfg.n_vertices, dtype=np.int64)[None, :]
        ks = np.arange(cfg.rhizome_cap, dtype=np.int64)[:, None]
        r, c, s = rhizome_rcs(cfg, vids, ks)                     # [R, n]
        vals = np.asarray(self.state.vals[..., 0])[r, c, s]
        on = np.asarray(self.state.rhz_on)[r, c, s]
        on[0, :] = True                # canonical root is always live
        v = functools.reduce(app.combine, vals)                  # [n]
        tgt = on & (v != np.float32(app.init_val))[None, :]
        kk, vv = np.nonzero(tgt)
        bits = np.ascontiguousarray(
            v[vv].astype(np.float32)).view(np.int32)
        return np.stack([vv.astype(np.int32),
                         (-(kk + 1)).astype(np.int32), bits],
                        axis=1).astype(np.int32)

    def _repair_rounds(self, limit, cycles, rings) -> int:
        """Bounded graceful-degradation pass: when the loss detector
        fires at end of increment, re-inject the durable values as
        OP_REPAIR traffic and re-run to quiescence under the plan's
        zero-rate twin (``FaultPlan.safe()`` — recovery rides a reliable
        transport, and the twin keeps every leaf shape so the state
        flows into the repair jit without reshaping)."""
        cfg = self.cfg
        plan = cfg.faults
        if self._loss_count() == 0:
            return cycles
        safe_cfg = dataclasses.replace(cfg, faults=plan.safe())
        for _ in range(plan.max_repair_rounds):
            before = self._loss_count()
            entries = self._repair_entries()
            if not len(entries):
                break                  # nothing durable to re-diffuse
            self.state, spill = load_stream(cfg, self.state, entries)
            cycles, q, noprog, _, spill = self._device_passes(
                safe_cfg, spill, limit, rings, cycles)
            if not q and noprog >= LIVELOCK_CHUNKS:
                _raise_livelock(
                    safe_cfg, cycle=cycles, chunk=cycles // cfg.chunk,
                    frames=(obs_frames.FrameLog.from_rings(rings)
                            if rings else None))
            if len(spill):
                raise RuntimeError(self._spill_msg(limit, spill))
            if self._loss_count() == before:
                break                  # clean round: fixpoint reached
        else:
            raise RuntimeError(
                f"repair budget exhausted: {plan.max_repair_rounds} "
                "rounds each lost messages — the repair transport is "
                "expected to be fault-free (FaultPlan.safe()); see "
                "DESIGN.md §9")
        return cycles

    # -- ingest guard: tm_hiw-gated admission (DESIGN §9) --

    def _ingest_limit(self) -> int | None:
        return self._ingest_budget if self.cfg.ingest_guard else None

    def _update_ingest_budget(self) -> None:
        """AIMD-style admission control from the action-queue hi-water
        telemetry: halve the per-load admission budget when any cell's AQ
        crested within the reserve band of ``queue_cap`` (the §4.2
        pre-wedge signature), double it back while the fabric runs below
        half the band."""
        cfg = self.cfg
        ceiling = cfg.queue_cap - cfg.aq_reserve - cfg.sys_reserve
        cap = cfg.io_cells * cfg.io_stream_cap
        hiw = int(np.asarray(jax.device_get(
            self.state.tm_hiw))[..., TM_HW_AQ].max())
        cur = cap if self._ingest_budget is None else self._ingest_budget
        if hiw >= ceiling:
            cur = max(cfg.io_cells, cur // 2)
        elif hiw < max(1, ceiling // 2):
            cur = min(cap, cur * 2)
        self._ingest_budget = cur

    # -- durable state: boundary checkpoint / restore (DESIGN §9) --

    def checkpoint(self, ckpt, step: int | None = None,
                   block: bool = True) -> int:
        """Publish the full machine pytree + stream cursor + config
        fingerprint through ``ckpt`` (a ``train.checkpoint.
        Checkpointer``).  Only sound at an increment boundary (which is
        where ``run_increment(ckpt=...)`` calls it).  ``block=False``
        snapshots to host and serializes on the writer thread."""
        from repro.resilience.checkpoint import stream_manifest
        step = self.stream_pos if step is None else step
        save = ckpt.save if block else ckpt.save_async
        save(step, self.state._asdict(), extra=stream_manifest(self))
        return step

    @classmethod
    def restore(cls, cfg: EngineConfig, app, ckpt,
                step: int | None = None, shardings=None,
                strict: bool = True, verify: bool = True):
        """Rebuild an engine from a boundary checkpoint: replaying the
        remaining stream from ``engine.stream_pos`` reproduces the
        uninterrupted run bit-exactly.  ``shardings`` may be a
        ``MachineState`` of NamedShardings (e.g. ``cca_state_shardings``)
        for elastic re-sharding onto the current mesh."""
        from repro.resilience.checkpoint import config_fingerprint
        eng = cls(cfg, app)
        like = jax.tree.map(np.asarray, eng.state._asdict())
        sh = (shardings._asdict() if isinstance(shardings, MachineState)
              else shardings)
        tree, extra, step = ckpt.restore(like, step=step, shardings=sh,
                                         verify=verify)
        if strict:
            fp = config_fingerprint(eng.cfg)
            if extra.get("config") != fp:
                raise ValueError(
                    f"checkpoint step {step} was saved under config "
                    f"{extra.get('config')}, engine is {fp}: restoring "
                    "across configs would reinterpret the address/queue "
                    "layout silently (strict=False only for post-mortem "
                    "inspection)")
            if extra.get("app") != eng.app.name:
                raise ValueError(
                    f"checkpoint app '{extra.get('app')}' != engine app "
                    f"'{eng.app.name}'")
        if sh is None:
            tree = {k: jnp.asarray(v) for k, v in tree.items()}
        eng.state = MachineState(**tree)
        eng.stream_pos = int(extra.get("stream_pos", step))
        eng.total_cycles = int(extra.get("total_cycles", 0))
        eng.totals.update({k: int(v) for k, v in
                           extra.get("totals", {}).items()})
        return eng

    def _spill_msg(self, limit, spill) -> str:
        # never drop work silently: the cycle limit ran out before the
        # spilled residue could be re-loaded and ingested
        return (f"cycle limit {limit} exhausted with {len(spill)} spilled "
                "edges not yet ingested; raise max_cycles or io_stream_cap "
                "(DESIGN.md §4.2).")

    def _finish_increment(self, cycles, hops, execs, stalls, allocs,
                          act, flt, frames=None) -> IncrementResult:
        self.total_cycles += cycles
        for k, v in zip(("hops", "execs", "stalls", "allocs"),
                        (hops, execs, stalls, allocs)):
            self.totals[k] += v
        return IncrementResult(
            cycles=cycles, active_per_cycle=act, in_flight_per_cycle=flt,
            hops=hops, execs=execs, stalls=stalls, allocs=allocs,
            frames=frames)

    # -- read back application values from the vertex objects --
    def values(self, n: int | None = None, val_idx: int = 0,
               combine=None) -> np.ndarray:
        """Min-reduce over every rhizome root of each vertex.

        The canonical root always holds the tightest value (all external
        relaxes land there; siblings only receive its snapshots), so for
        the bundled monotone-min apps the reduce equals the canonical
        value — kept as a reduce so readback stays correct even mid-run.

        ``combine`` overrides the app-level root reduce — a qbatch
        composite passes the PER-SLOT combine of the query living in
        ``val_idx`` (repro.mq readback, DESIGN §10).
        """
        cfg = self.cfg
        n = n or cfg.n_vertices
        # one batched gather over all (root k, vertex) pairs instead of a
        # python loop of per-k fancy indexing
        vids = np.arange(n, dtype=np.int64)[None, :]
        ks = np.arange(cfg.rhizome_cap, dtype=np.int64)[:, None]
        r, c, s = rhizome_rcs(cfg, vids, ks)                     # [R, n]
        v = np.asarray(self.state.vals[..., val_idx])[r, c, s]
        return functools.reduce(combine or self.app.combine, v)

    def vertex_object_stats(self) -> dict:
        """Diagnostics over the hierarchical vertex objects: ghost usage +
        locality (validates Fig. 5 policies) plus rhizome fan-out and the
        spread of co-equal roots over the mesh (DESIGN §4.5)."""
        cfg = self.cfg
        st = self.state
        gs = np.asarray(st.gstate)
        ga = np.asarray(st.gaddr)
        used = int(np.sum(np.asarray(st.nfree) - cfg.primary_slots))
        out = dict(ghosts=used, mean_hops=0.0, max_hops=0,
                   rhizomes=0, multi_root_vertices=0, max_fanout=1,
                   mean_rhizome_hops=0.0)
        have = gs == 2
        if have.any():
            rr, cc, _ = np.nonzero(have)
            tgt_cell = ga[have] // cfg.slots
            tr, tc = tgt_cell // cfg.width, tgt_cell % cfg.width
            d = np.abs(rr - tr) + np.abs(cc - tc)
            out.update(mean_hops=float(d.mean()), max_hops=int(d.max()))
        if cfg.rhizome_cap > 1:
            on = np.asarray(st.rhz_on)          # [H,W,S]
            # batched gather over all (root k, vertex) pairs (no per-k
            # python loop): rows 1.. are the secondary roots
            vids = np.arange(cfg.n_vertices, dtype=np.int64)[None, :]
            ks = np.arange(cfg.rhizome_cap, dtype=np.int64)[:, None]
            r, c, s = rhizome_rcs(cfg, vids, ks)                 # [R, n]
            act = on[r, c, s][1:]                                # [R-1, n]
            fan = 1 + act.sum(axis=0)
            d = np.abs(r[1:] - r[0]) + np.abs(c[1:] - c[0])      # [R-1, n]
            out.update(
                rhizomes=int(fan.sum() - cfg.n_vertices),
                multi_root_vertices=int((fan > 1).sum()),
                max_fanout=int(fan.max()),
                mean_rhizome_hops=(float(d[act].mean())
                                   if act.any() else 0.0))
        return out
