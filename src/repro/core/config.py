"""Engine configuration for the AM-CCA-style message-driven machine.

The paper simulates a 32x32 chip of Compute Cells (CCs), each with local
memory (vertex slots), an action queue, and four mesh links (N/S/E/W) with
one-hop-per-cycle YX dimension-ordered routing.  All capacities below are
static so the whole machine state is a fixed-shape JAX pytree.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    # --- chip geometry (paper: 32x32) ---
    height: int = 32
    width: int = 32

    # --- RPVO storage ---
    n_vertices: int = 1024        # logical vertices (roots, round-robin placed)
    edge_cap: int = 8             # edges per RPVO node before spilling to ghost
    ghost_slots: int = 64         # ghost slots per cell (beyond root slots)

    # --- queues / buffers ---
    queue_cap: int = 32           # per-cell action queue
    chan_cap: int = 8             # per-cell per-direction outgoing channel
    futq_cap: int = 8             # per-future deferred-task queue (Fig. 4)

    # --- IO channels (paper: IO cells stream edges, 1 edge/cycle each) ---
    n_io_cells: int = 0           # 0 -> one per column (paper-style)
    io_stream_cap: int = 4096     # per-IO-cell residual stream capacity

    # --- allocation policy (paper Fig. 5) ---
    allocator: str = "vicinity"   # "vicinity" (<=2 hops) | "random"
    vicinity_hops: int = 2

    # --- app ---
    n_vals: int = 1               # per-slot application values (BFS: level)

    # --- engine ---
    max_cycles: int = 1_000_000
    chunk: int = 256              # cycles per jitted scan chunk

    @property
    def n_cells(self) -> int:
        return self.height * self.width

    @property
    def root_slots(self) -> int:
        return int(math.ceil(self.n_vertices / self.n_cells))

    @property
    def slots(self) -> int:
        return self.root_slots + self.ghost_slots

    @property
    def io_cells(self) -> int:
        return self.n_io_cells if self.n_io_cells > 0 else self.width

    @property
    def aq_reserve(self) -> int:
        # Reserved action-queue slots so the active action's *local*
        # emissions always complete -> no self-deadlock (see DESIGN 4.2).
        return self.edge_cap + 2

    @property
    def sys_reserve(self) -> int:
        # System actions (allocate / set-future) may fill the queue this
        # much further than application messages: combined with head
        # rotation this guarantees the future-LCO protocol always makes
        # progress under congestion (no FIFO head-of-line deadlock).
        return 2

    def validate(self) -> None:
        assert self.height >= 2 and self.width >= 2
        assert self.queue_cap > self.aq_reserve + self.sys_reserve + 1, \
            "queue too small for reserves"
        assert self.n_cells * self.slots < 2**31, "address overflows int32"
        assert self.edge_cap >= 1 and self.futq_cap >= 2
