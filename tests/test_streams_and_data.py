"""Stream generators, neighbor sampler, data-pipeline determinism."""
import numpy as np

from repro.data.graphs import NeighborSampler, sampled_subgraph_sizes
from repro.data.pipeline import (LMBatchSpec, RecSysBatchSpec, lm_batch,
                                 recsys_batch)
from repro.graph.streams import StreamSpec, make_stream, sbm_edges


def test_sbm_edges_unique_and_sized():
    spec = StreamSpec(n_vertices=200, n_edges=1500, seed=4)
    e = sbm_edges(spec)
    assert e.shape == (1500, 2)
    assert (e[:, 0] != e[:, 1]).all()
    keys = set(map(tuple, e.tolist()))
    assert len(keys) == 1500  # unique


def test_edge_stream_partitions_everything():
    spec = StreamSpec(n_vertices=100, n_edges=600, increments=10, seed=1)
    incs = make_stream(spec)
    assert len(incs) == 10
    sizes = [len(x) for x in incs]
    assert max(sizes) - min(sizes) <= 1          # ~equal (paper Table 1)
    assert sum(sizes) == 600


def test_snowball_stream_grows():
    spec = StreamSpec(n_vertices=100, n_edges=600, increments=5,
                      sampling="snowball", seed=2)
    incs = make_stream(spec)
    sizes = [len(x) for x in incs]
    assert sum(sizes) == 600
    assert sizes[-1] > sizes[0]                  # growing (paper Table 1)


def test_neighbor_sampler_shapes_and_edges():
    rng = np.random.default_rng(0)
    n = 500
    src = rng.integers(0, n, 4000).astype(np.int32)
    dst = rng.integers(0, n, 4000).astype(np.int32)
    s = NeighborSampler(n, np.stack([src, dst]))
    seeds = rng.integers(0, n, 32).astype(np.int64)
    out = s.sample(seeds, fanout=(5, 3))
    n_nodes, n_edges = sampled_subgraph_sizes(
        dict(batch_nodes=32, fanout=(5, 3)))
    assert out["node_ids"].shape == (n_nodes,)
    assert out["edge_index"].shape == (2, n_edges)
    # edges point child -> parent, parents come earlier in the node list
    assert (out["edge_index"][0] > out["edge_index"][1]).all()
    assert out["edge_index"].max() < n_nodes


def test_pipeline_determinism():
    spec = LMBatchSpec(batch=4, seq_len=32, vocab=1000, seed=9)
    a = lm_batch(spec, 17)
    b = lm_batch(spec, 17)
    c = lm_batch(spec, 18)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()
    rs = RecSysBatchSpec(batch=8, n_dense=4, n_sparse=3, lookups=2,
                         vocab_sizes=(64, 32, 16), seed=3)
    x = recsys_batch(rs, 5)
    y = recsys_batch(rs, 5)
    np.testing.assert_array_equal(x["sparse"], y["sparse"])
    assert x["sparse"].shape == (8, 3, 2)
    for f, v in enumerate((64, 32, 16)):
        assert x["sparse"][:, f].max() < v


def test_adamw_optimizes_quadratic():
    import jax
    import jax.numpy as jnp
    from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    params = dict(w=jnp.array([3.0, -2.0]))
    opt = init_adamw(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-2
