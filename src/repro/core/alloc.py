"""Ghost-vertex allocation policies (paper Fig. 5).

The *vicinity allocator* keeps ghost vertices within ``vicinity_hops``
(default 2, per the paper) of the requesting cell, minimizing intra-vertex
(root <-> ghost chain) operation latency.  The *random allocator* disperses
them uniformly.  Target choice happens at the requesting cell when it
stages the ``allocate`` system action; a rotating per-cell counter makes the
choice deterministic yet spread out.  If the chosen cell is full, its
``allocate`` handler forwards the request to the next cell (linear probe).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.config import EngineConfig


def rhizome_cell(cfg: EngineConfig, vid, k):
    """Cell of rhizome root ``k`` of vertex ``vid`` (static placement).

    Root 0 is the classic canonical root (cell ``vid % n_cells``); roots
    k >= 1 are scattered ``k * rhizome_stride`` cells away so the co-equal
    roots of a hub vertex spread over the mesh (DESIGN §4.5).
    """
    vid = jnp.asarray(vid, jnp.int32)
    k = jnp.asarray(k, jnp.int32)
    return (vid + k * cfg.rhizome_stride) % cfg.n_cells


def rhizome_addr(cfg: EngineConfig, vid, k):
    """Global address of rhizome root ``k`` of vertex ``vid``.

    Slot layout: rhizome k of the vertex with local index j = vid // n_cells
    occupies slot ``k * root_slots + j`` of its cell, so the primary region
    [0, rhizome_cap * root_slots) is statically partitioned and the ghost
    allocator starts above it.
    """
    vid = jnp.asarray(vid, jnp.int32)
    k = jnp.asarray(k, jnp.int32)
    slot = k * cfg.root_slots + vid // cfg.n_cells
    return rhizome_cell(cfg, vid, k) * cfg.slots + slot


def rhizome_rcs(cfg: EngineConfig, vid, k):
    """Host-side placement: (row, col, slot) of rhizome root ``k`` of
    ``vid``.  Pure-python/numpy arithmetic (no jnp) so the engine's host
    readback, seeding and stats share one copy of the layout formulas."""
    cell = (vid + k * cfg.rhizome_stride) % cfg.n_cells
    return (cell // cfg.width, cell % cfg.width,
            k * cfg.root_slots + vid // cfg.n_cells)


def rhizome_owner_vid(cfg: EngineConfig, cellid, slot):
    """Inverse placement map: vertex id owning primary ``slot`` of ``cellid``.

    Only meaningful for slots in the primary region; used by a pending
    rhizome root to address OP_LINK_RHIZOME at its canonical root.
    """
    k = slot // cfg.root_slots
    j = slot % cfg.root_slots
    home = (cellid - k * cfg.rhizome_stride) % cfg.n_cells
    return j * cfg.n_cells + home


def vicinity_offsets(hops: int) -> np.ndarray:
    """(dy, dx) ring offsets with Chebyshev distance in [1, hops]."""
    offs = [(dy, dx)
            for dy in range(-hops, hops + 1)
            for dx in range(-hops, hops + 1)
            if max(abs(dy), abs(dx)) >= 1]
    # sort nearest-first so rotation prefers 1-hop neighbours
    offs.sort(key=lambda p: (max(abs(p[0]), abs(p[1])), p))
    return np.asarray(offs, np.int32)


def choose_alloc_cell(cfg: EngineConfig, rows, cols, arot):
    """Vectorized target-cell choice.  rows/cols/arot: [H,W] int32.

    Returns [H,W] flat cell ids.
    """
    H, W = cfg.height, cfg.width
    if cfg.allocator == "random":
        # splitmix-style integer hash of (cell, rotation) -> uniform cell
        x = (rows * W + cols).astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
        x = x + arot.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
        x ^= x >> 16
        x = x * jnp.uint32(0xC2B2AE35)
        x ^= x >> 13
        return (x % jnp.uint32(cfg.n_cells)).astype(jnp.int32)
    offs = vicinity_offsets(cfg.vicinity_hops).tolist()      # [K][2] ints
    k = arot % len(offs)
    # select the (dy, dx) ring offset by a where-chain over the static
    # table instead of gathering from a device-resident constant array:
    # identical results, but the offsets embed as scalar literals, so
    # this traces inside the Pallas cycle megakernel (which cannot close
    # over array constants) as well as in the jnp path.
    dy = jnp.zeros_like(arot)
    dx = jnp.zeros_like(arot)
    for i, (oy, ox) in enumerate(offs):
        m = k == i
        dy = jnp.where(m, oy, dy)
        dx = jnp.where(m, ox, dx)
    r = jnp.clip(rows + dy, 0, H - 1)
    c = jnp.clip(cols + dx, 0, W - 1)
    return r * W + c
