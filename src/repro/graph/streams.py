"""Streaming dynamic graph generators — GraphChallenge-style (paper §4).

The paper uses MIT GraphChallenge stochastic-block-partition streaming
graphs (Table 1): 50K/500K vertices, ~1.0M/10.2M edges, delivered in ten
increments under two sampling regimes:

  * **Edge sampling**   — edges arrive in random (real-world observation)
    order, so increments have near-equal size.
  * **Snowball sampling** — edges arrive as discovered by an expanding
    frontier from a start vertex, so increments grow monotonically
    (the paper's Table 1 shows 37K -> 191K for the 50K graph).

The datasets are offline here, so we synthesize stochastic-block-model
graphs of the same shape and stream them with the same two samplers.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    n_vertices: int = 50_000
    n_edges: int = 1_000_000
    n_blocks: int = 32          # SBM community count
    p_in_over_p_out: float = 16.0
    increments: int = 10
    sampling: str = "edge"      # "edge" | "snowball"
    seed: int = 0
    symmetric: bool = False     # insert both directions
    kind: str = "sbm"           # "sbm" | "rmat" (power-law skew)
    # R-MAT quadrant probabilities (a,b,c; d = 1-a-b-c).  The defaults are
    # the Graph500 parameters, giving a power-law degree distribution with
    # heavy hubs — the skewed-stream regime rhizomes target (DESIGN §4.5).
    rmat_a: float = 0.57
    rmat_b: float = 0.19
    rmat_c: float = 0.19


def sbm_edges(spec: StreamSpec) -> np.ndarray:
    """Sample ~n_edges unique directed edges of a stochastic block model."""
    rng = np.random.default_rng(spec.seed)
    V, B = spec.n_vertices, spec.n_blocks
    block = rng.integers(0, B, size=V)
    m = 0
    chunks = []
    seen = set()
    # rejection-sample: propose intra-block with prob prop. to p_in ratio
    p_intra = spec.p_in_over_p_out / (spec.p_in_over_p_out + B - 1)
    while m < spec.n_edges:
        k = min(4 * (spec.n_edges - m) + 1024, 4_000_000)
        src = rng.integers(0, V, size=k)
        intra = rng.random(k) < p_intra
        # intra: dst from same block; inter: uniform
        dst = rng.integers(0, V, size=k)
        # resample intra dsts from src's block by jittering within block lists
        order = np.argsort(block, kind="stable")
        starts = np.searchsorted(block[order], np.arange(B))
        ends = np.searchsorted(block[order], np.arange(B), side="right")
        b = block[src]
        lo, hi = starts[b], ends[b]
        pick = lo + (rng.integers(0, 1 << 30, size=k) % np.maximum(hi - lo, 1))
        dst = np.where(intra, order[pick], dst)
        ok = src != dst
        src, dst = src[ok], dst[ok]
        for s, d in zip(src, dst):
            key = (int(s) << 32) | int(d)
            if key not in seen:
                seen.add(key)
                chunks.append((s, d))
                m += 1
                if m >= spec.n_edges:
                    break
    e = np.asarray(chunks, dtype=np.int64)
    return e.astype(np.int32)


def rmat_edges(spec: StreamSpec) -> np.ndarray:
    """Sample ~n_edges directed edges of an R-MAT (Kronecker) graph.

    Vertices are drawn bit-by-bit through the recursive quadrant matrix
    [[a, b], [c, d]]; with Graph500 parameters the out-degree distribution
    is power-law, so a handful of hub vertices receive degrees tens of
    times ``edge_cap`` — the pathological case for a serial ghost chain.
    Self-loops are dropped; duplicate edges are kept (they re-arrive in
    real streams and are legal inserts).
    """
    rng = np.random.default_rng(spec.seed)
    scale = max(1, int(np.ceil(np.log2(max(spec.n_vertices, 2)))))
    a, b, c = spec.rmat_a, spec.rmat_b, spec.rmat_c
    d = 1.0 - a - b - c
    assert d >= 0, "rmat probabilities exceed 1"
    src = np.zeros(0, np.int64)
    dst = np.zeros(0, np.int64)
    while len(src) < spec.n_edges:
        k = spec.n_edges - len(src) + 1024
        s = np.zeros(k, np.int64)
        t = np.zeros(k, np.int64)
        for _ in range(scale):
            q = rng.random(k)
            down = (q >= a + b).astype(np.int64)            # rows c/d
            right = (((q >= a) & (q < a + b))
                     | (q >= a + b + c)).astype(np.int64)   # cols b/d
            s = (s << 1) | down
            t = (t << 1) | right
        ok = (s != t) & (s < spec.n_vertices) & (t < spec.n_vertices)
        src = np.concatenate([src, s[ok]])
        dst = np.concatenate([dst, t[ok]])
    src, dst = src[:spec.n_edges], dst[:spec.n_edges]
    return np.stack([src, dst], axis=1).astype(np.int32)


def hub_edges(n_vertices: int, hub: int, degree: int,
              seed: int = 0) -> np.ndarray:
    """A single hub of the given out-degree plus a random tail — the
    minimal skewed stream for pinning rhizome correctness in tests."""
    rng = np.random.default_rng(seed)
    dsts = 1 + (np.arange(degree, dtype=np.int64) % (n_vertices - 1))
    dsts = np.where(dsts == hub, 0, dsts)   # no self-loops
    e = [np.stack([np.full(degree, hub, np.int64), dsts], axis=1)]
    # sparse tail so BFS has depth beyond the hub fan-out
    t_src = rng.integers(0, n_vertices, n_vertices // 2)
    t_dst = rng.integers(0, n_vertices, n_vertices // 2)
    ok = t_src != t_dst
    e.append(np.stack([t_src[ok], t_dst[ok]], axis=1))
    return np.concatenate(e).astype(np.int32)


def edge_sampled_stream(edges: np.ndarray, increments: int,
                        seed: int = 0) -> list[np.ndarray]:
    """Random arrival order, equal-size increments (Table 1 'Edge')."""
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(len(edges))
    parts = np.array_split(perm, increments)
    return [edges[p] for p in parts]


def snowball_stream(edges: np.ndarray, increments: int, source: int = 0,
                    seed: int = 0) -> list[np.ndarray]:
    """Edges arrive as discovered by BFS from `source` (Table 1 'Snowball').

    Produces monotonically growing increments like the paper by splitting
    the discovery order at quadratically spaced cut points.
    """
    n = int(max(edges[:, 0].max(), edges[:, 1].max())) + 1
    # adjacency (undirected discovery like the GraphChallenge snowball)
    order = np.zeros(len(edges), dtype=np.int64)
    adj_idx = {}
    for i, (s, d) in enumerate(edges):
        adj_idx.setdefault(int(s), []).append(i)
        adj_idx.setdefault(int(d), []).append(i)
    seen_v = np.zeros(n, bool)
    seen_e = np.zeros(len(edges), bool)
    outq = [source]
    seen_v[source] = True
    pos = 0
    k = 0
    while outq:
        nxt = []
        for v in outq:
            for ei in adj_idx.get(v, ()):
                if not seen_e[ei]:
                    seen_e[ei] = True
                    order[k] = ei
                    k += 1
                    s, d = edges[ei]
                    for u in (int(s), int(d)):
                        if not seen_v[u]:
                            seen_v[u] = True
                            nxt.append(u)
        outq = nxt
    # disconnected leftovers arrive last
    rest = np.nonzero(~seen_e)[0]
    order[k:k + len(rest)] = rest
    k += len(rest)
    order = order[:k]
    # quadratic cut points -> growing increments (paper Table 1 pattern)
    w = np.arange(1, increments + 1, dtype=np.float64)
    cuts = np.cumsum(w / w.sum()) * k
    cuts = np.unique(np.round(cuts).astype(np.int64))[:-1]
    return [edges[p] for p in np.split(order, cuts)]


def make_stream(spec: StreamSpec) -> list[np.ndarray]:
    if spec.kind == "rmat":
        edges = rmat_edges(spec)
    elif spec.kind == "sbm":
        edges = sbm_edges(spec)
    else:
        raise ValueError(spec.kind)
    if spec.symmetric:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    if spec.sampling == "edge":
        incs = edge_sampled_stream(edges, spec.increments, spec.seed)
    elif spec.sampling == "snowball":
        incs = snowball_stream(edges, spec.increments, source=0,
                               seed=spec.seed)
    else:
        raise ValueError(spec.sampling)
    # attach unit weights (bit pattern of 1.0f)
    one = np.float32(1.0).view(np.int32)
    return [np.concatenate([e, np.full((len(e), 1), one, np.int32)], axis=1)
            for e in incs]
