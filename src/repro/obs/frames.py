"""Telemetry frames: per-chunk snapshots of the machine's telemetry
planes, kept in a fixed-size on-device ring (DESIGN §8).

A **frame** is one snapshot of the cumulative telemetry planes plus the
instantaneous queue depths and the scalar counter row, taken once per
chunk inside the sync-free device loop (``engine._increment_device_loop``)
— no host sync per chunk.  The ring holds ``cfg.frame_ring`` frames and
overwrites ring-style; it is read back as ONE batched transfer at the
end of each increment pass, together with the scalar record the fast
path already fetched.

Because the planes are cumulative over an increment (reset with the
``stat_*`` scalars), the FINAL frame reconciles exactly with the scalar
counters, and per-chunk activity is recovered by differencing
consecutive frames (:meth:`FrameLog.deltas`) — which is what the
flight recorder and the Chrome-trace exporter consume.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import EngineConfig
from repro.core.state import MachineState

# ---- frame scalar row indices (``Frame.scal [N_FS]``) ----
FS_CYCLE = 0      # machine cycle at snapshot time
FS_HOPS = 1       # cumulative stat_hops (this increment)
FS_EXEC = 2       # cumulative stat_exec
FS_STALL = 3      # cumulative stat_stall
FS_ALLOCS = 4     # cumulative stat_allocs
FS_BACKLOG = 5    # instantaneous sum of action-queue depths
FS_INFLIGHT = 6   # instantaneous channel + park-ring occupancy
FS_QUIESCENT = 7  # machine quiescent at snapshot time (0/1)
N_FS = 8


class FrameRing(NamedTuple):
    """On-device ring of the last ``F = cfg.frame_ring`` frames.

    Every leaf carries a leading ``[F]`` axis; ``n`` counts frames
    written in total (monotone — it may exceed ``F``, in which case the
    oldest frames were overwritten).  A plain pytree, so it rides a
    ``lax.while_loop`` carry and a single ``jax.device_get``.
    """
    cell: jax.Array   # [F,H,W,N_TM_STAGES] cumulative stage activity
    lane: jax.Array   # [F,H,W,4,L,N_TM_LANE] cumulative lane counters
    hiw: jax.Array    # [F,H,W,N_TM_HIW] AQ/park hi-water marks
    aq_n: jax.Array   # [F,H,W] instantaneous action-queue depth
    pk_n: jax.Array   # [F,H,W] instantaneous park-ring depth
    ch_n: jax.Array   # [F,H,W,4,L] instantaneous lane occupancy
    scal: jax.Array   # [F,N_FS] scalar counter row
    n: jax.Array      # scalar i32: frames written (total)


def init_ring(cfg: EngineConfig) -> FrameRing:
    """Zeroed ring for one increment pass (requires ``cfg.telemetry``)."""
    from repro.core.state import N_TM_HIW, N_TM_LANE, N_TM_STAGES
    F, H, W, L = cfg.frame_ring, cfg.height, cfg.width, cfg.lanes
    z = lambda *s: jnp.zeros(s, jnp.int32)
    return FrameRing(
        cell=z(F, H, W, N_TM_STAGES), lane=z(F, H, W, 4, L, N_TM_LANE),
        hiw=z(F, H, W, N_TM_HIW), aq_n=z(F, H, W), pk_n=z(F, H, W),
        ch_n=z(F, H, W, 4, L), scal=z(F, N_FS), n=jnp.int32(0))


def snapshot(cfg: EngineConfig, st: MachineState) -> dict:
    """One frame (no leading ``F`` axis) from the current state.

    Traceable — called once per chunk inside the device loop, and by the
    traced host loop for the same schema.
    """
    from repro.core.engine import quiescent  # deferred: engine imports us
    scal = jnp.stack([
        st.cycle, st.stat_hops, st.stat_exec, st.stat_stall, st.stat_allocs,
        jnp.sum(st.aq_n), jnp.sum(st.ch_n) + jnp.sum(st.pk_n),
        quiescent(st).astype(jnp.int32)])
    return dict(cell=st.tm_cell, lane=st.tm_lane, hiw=st.tm_hiw,
                aq_n=st.aq_n, pk_n=st.pk_n, ch_n=st.ch_n, scal=scal)


def ring_store(ring: FrameRing, frame: dict) -> FrameRing:
    """Write ``frame`` at slot ``n % F`` and advance ``n`` (traceable)."""
    F = ring.scal.shape[0]
    slot = ring.n % F

    def upd(r, f):
        return jax.lax.dynamic_update_index_in_dim(r, f.astype(r.dtype),
                                                   slot, 0)

    return FrameRing(
        cell=upd(ring.cell, frame["cell"]), lane=upd(ring.lane, frame["lane"]),
        hiw=upd(ring.hiw, frame["hiw"]), aq_n=upd(ring.aq_n, frame["aq_n"]),
        pk_n=upd(ring.pk_n, frame["pk_n"]), ch_n=upd(ring.ch_n, frame["ch_n"]),
        scal=upd(ring.scal, frame["scal"]), n=ring.n + 1)


_PLANES = ("cell", "lane", "hiw", "aq_n", "pk_n", "ch_n", "scal")


@dataclasses.dataclass
class FrameLog:
    """Host-side, time-ordered frame sequence (numpy, oldest first).

    Built from the device ring(s) of an increment (one ring per spill
    pass — the cumulative counters continue monotonically across
    passes, so concatenation preserves the difference structure).
    """
    cell: np.ndarray   # [N,H,W,N_TM_STAGES]
    lane: np.ndarray   # [N,H,W,4,L,N_TM_LANE]
    hiw: np.ndarray    # [N,H,W,N_TM_HIW]
    aq_n: np.ndarray   # [N,H,W]
    pk_n: np.ndarray   # [N,H,W]
    ch_n: np.ndarray   # [N,H,W,4,L]
    scal: np.ndarray   # [N,N_FS]
    dropped: int = 0   # frames overwritten in the ring before readback

    def __len__(self) -> int:
        return int(self.scal.shape[0])

    @classmethod
    def from_rings(cls, rings) -> "FrameLog":
        """Unroll one or more device rings (already on host) into time
        order: ring slot ``i % F`` holds frame ``i``, so the surviving
        window is ``[max(0, n - F), n)``."""
        parts = {k: [] for k in _PLANES}
        dropped = 0
        for ring in rings:
            n = int(ring.n)
            if n == 0:
                continue
            F = ring.scal.shape[0]
            k = min(n, F)
            idx = np.arange(n - k, n) % F
            dropped += max(0, n - F)
            for name in _PLANES:
                parts[name].append(np.asarray(getattr(ring, name))[idx])
        if not parts["scal"]:
            raise ValueError("no frames recorded (empty ring)")
        arrs = {k: np.concatenate(v, axis=0) for k, v in parts.items()}
        return cls(**arrs, dropped=dropped)

    # -- reductions ---------------------------------------------------

    def last(self) -> dict:
        """The final frame's planes (cumulative over the increment)."""
        return {k: getattr(self, k)[-1] for k in _PLANES}

    def totals(self) -> dict:
        """Scalar totals of the final frame — the reconciliation surface
        against the engine's ``IncrementResult`` counters."""
        s = self.scal[-1]
        return dict(cycle=int(s[FS_CYCLE]), hops=int(s[FS_HOPS]),
                    execs=int(s[FS_EXEC]), stalls=int(s[FS_STALL]),
                    allocs=int(s[FS_ALLOCS]), backlog=int(s[FS_BACKLOG]),
                    in_flight=int(s[FS_INFLIGHT]),
                    quiescent=bool(s[FS_QUIESCENT]))

    def deltas(self) -> dict:
        """Per-frame activity: consecutive differences of the cumulative
        planes/counters (first frame differenced against zero — the
        counters reset at increment start).  Instantaneous fields
        (``aq_n``/``pk_n``/``ch_n``/``hiw``) pass through unchanged."""
        z_cell = np.zeros_like(self.cell[:1])
        z_lane = np.zeros_like(self.lane[:1])
        z_scal = np.zeros_like(self.scal[:1])
        if self.dropped:
            # the window start is not cycle 0: difference within the
            # window only (the first surviving frame keeps its cumulative
            # value as its "delta" otherwise — misleading; drop it)
            return dict(
                cell=np.diff(self.cell, axis=0),
                lane=np.diff(self.lane, axis=0),
                scal=np.diff(self.scal, axis=0),
                aq_n=self.aq_n[1:], pk_n=self.pk_n[1:],
                ch_n=self.ch_n[1:], hiw=self.hiw[1:])
        return dict(
            cell=np.diff(np.concatenate([z_cell, self.cell]), axis=0),
            lane=np.diff(np.concatenate([z_lane, self.lane]), axis=0),
            scal=np.diff(np.concatenate([z_scal, self.scal]), axis=0),
            aq_n=self.aq_n, pk_n=self.pk_n, ch_n=self.ch_n, hiw=self.hiw)
