"""``repro.obs`` — sync-free on-device telemetry (DESIGN §8).

The observability layer rides the engine's fast path instead of
bypassing it:

* ``frames``  — the per-chunk snapshot schema (:class:`Frame` fields),
  the fixed-size on-device :class:`FrameRing` carried through the
  sync-free device loop, and the host-side :class:`FrameLog` readback;
* ``flight``  — the livelock flight recorder: post-mortem wedge
  analysis over the last recorded frames and the rendered
  "who is wedged" report attached to :class:`LivelockError`;
* ``export``  — Chrome ``trace_event`` JSON (one track per stage, one
  per link lane) and the congestion-heatmap dump consumed by
  ``benchmarks/report.py``;
* ``metrics`` — small latency/throughput summary helpers used by the
  serving surface (``launch/serve.py``).

The telemetry planes themselves live in ``core.state.MachineState``
(``tm_cell`` / ``tm_lane`` / ``tm_hiw``) and are accumulated inside the
cycle stages when ``EngineConfig.telemetry`` is on — both backends (jnp
chunk runners and the Pallas cycle megakernel) inherit them through
``cycle_body`` with zero extra host syncs.
"""
from repro.obs.export import (chrome_trace, congestion_heatmap,
                              write_chrome_trace, write_heatmap)
from repro.obs.flight import (render_wedge_report, wedged_cells,
                              wedged_lanes)
from repro.obs.frames import (FS_ALLOCS, FS_BACKLOG, FS_CYCLE, FS_EXEC,
                              FS_HOPS, FS_INFLIGHT, FS_QUIESCENT, FS_STALL,
                              FrameLog, FrameRing, init_ring, ring_store,
                              snapshot)
from repro.obs.metrics import engine_rates, render_summary, summarize

__all__ = [
    "FrameLog", "FrameRing", "init_ring", "ring_store", "snapshot",
    "FS_CYCLE", "FS_HOPS", "FS_EXEC", "FS_STALL", "FS_ALLOCS",
    "FS_BACKLOG", "FS_INFLIGHT", "FS_QUIESCENT",
    "chrome_trace", "congestion_heatmap", "write_chrome_trace",
    "write_heatmap", "render_wedge_report", "wedged_cells", "wedged_lanes",
    "engine_rates", "render_summary", "summarize",
]
