"""Central architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs import cca_paper, gnn_archs, lm_archs, recsys_archs
from repro.configs.base import ArchBundle


def all_bundles() -> dict[str, ArchBundle]:
    out = {}
    for mod in (lm_archs, gnn_archs, recsys_archs, cca_paper):
        for b in mod.bundles():
            out[b.arch_id] = b
    return out


ARCHS = all_bundles()
ASSIGNED = [a for a in ARCHS if ARCHS[a].family != "cca"]


def get(arch_id: str) -> ArchBundle:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; have: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_shape(arch_id: str, shape_name: str):
    b = get(arch_id)
    for s in b.shapes:
        if s.name == shape_name:
            return b, s
    raise KeyError(f"{arch_id} has no shape '{shape_name}'; "
                   f"have {[s.name for s in b.shapes]}")


def cells():
    """All (arch, shape) dry-run cells."""
    return [(a, s.name) for a in ARCHS for s in ARCHS[a].shapes]
