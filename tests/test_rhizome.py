"""Rhizomatic vertex objects (DESIGN §4.5): skewed-stream correctness.

A hub vertex whose degree exceeds ``edge_cap * rhizome_cap`` forces both
the rhizome-link growth protocol and per-root ghost chains.  BFS / SSSP /
CC must reach the exact host-reference fixpoint for ``rhizome_cap`` 1
(chain-equivalence pin) and 4 (multi-root), and the multi-root run must
actually grow co-equal roots.
"""
import numpy as np
import pytest

from repro.core import EngineConfig, StreamingEngine
from repro.core.reference import bfs_levels, cc_labels, sssp_dists
from repro.graph.streams import StreamSpec, hub_edges, make_stream, rmat_edges

ONE = np.float32(1.0).view(np.int32)


def cfg_for(R, **kw):
    # queue_cap is sized for hub-convergent streams: with a serial chain
    # (R=1) every hub insert converges on one cell, and the queue must
    # hold the in-flight pile-up or the §4.2 livelock detector fires
    base = dict(height=8, width=8, n_vertices=64, edge_cap=4,
                ghost_slots=32, queue_cap=96, chan_cap=16, futq_cap=8,
                io_stream_cap=2048, chunk=128, rhizome_cap=R)
    base.update(kw)
    return EngineConfig(**base)


def with_weights(e2, w=None):
    if w is None:
        wbits = np.full((len(e2), 1), ONE, np.int32)
    else:
        wbits = w.astype(np.float32).view(np.int32).reshape(-1, 1)
    return np.concatenate([e2.astype(np.int32), wbits], axis=1)


@pytest.mark.parametrize("R", [1, 4])
def test_hub_bfs_exact(R):
    n, deg = 64, 40  # degree > edge_cap * rhizome_cap = 16
    e2 = hub_edges(n, hub=0, degree=deg, seed=3)
    edges = with_weights(e2)
    eng = StreamingEngine(cfg_for(R), "bfs")
    eng.seed(0, 0.0)
    eng.run_increment(edges, max_cycles=500_000)
    np.testing.assert_array_equal(eng.values(n), bfs_levels(n, edges, 0))
    stats = eng.vertex_object_stats()
    if R > 1:
        assert stats["multi_root_vertices"] >= 1
        assert stats["max_fanout"] > 1
    else:
        assert stats["rhizomes"] == 0


@pytest.mark.parametrize("R", [1, 4])
def test_hub_sssp_exact(R):
    n, deg = 64, 40
    rng = np.random.default_rng(5)
    e2 = hub_edges(n, hub=0, degree=deg, seed=5)
    w = rng.integers(1, 9, len(e2)).astype(np.float32)
    edges = with_weights(e2, w)
    eng = StreamingEngine(cfg_for(R), "sssp")
    eng.seed(0, 0.0)
    eng.run_increment(edges, max_cycles=500_000)
    want = sssp_dists(n, e2, w, 0)
    np.testing.assert_allclose(eng.values(n), want, rtol=1e-6)


@pytest.mark.parametrize("R", [1, 4])
def test_hub_cc_exact(R):
    n, deg = 64, 40
    e2 = hub_edges(n, hub=0, degree=deg, seed=7)
    e2 = np.concatenate([e2, e2[:, ::-1]], axis=0)  # undirected
    edges = with_weights(e2)
    eng = StreamingEngine(cfg_for(R), "cc")
    for v in range(n):
        eng.seed(v, float(v))
    eng.run_increment(edges, max_cycles=500_000)
    np.testing.assert_array_equal(eng.values(n), cc_labels(n, e2))


@pytest.mark.parametrize("R", [1, 4])
def test_edge_conservation_across_rhizomes(R):
    """No insert is lost or duplicated across co-equal roots + chains."""
    n, deg = 64, 48
    e2 = hub_edges(n, hub=0, degree=deg, seed=9)
    edges = with_weights(e2)
    eng = StreamingEngine(cfg_for(R), "ingest_only")
    eng.run_increment(edges, max_cycles=500_000)
    total = int(np.asarray(eng.state.nedges).sum())
    assert total == len(edges)


def test_rmat_stream_bfs_exact_multiroot():
    """Power-law (R-MAT) stream over increments, rhizome_cap=4."""
    spec = StreamSpec(n_vertices=128, n_edges=1024, increments=3,
                      kind="rmat", seed=11)
    incs = make_stream(spec)
    eng = StreamingEngine(cfg_for(4, n_vertices=128, ghost_slots=48), "bfs")
    eng.seed(0, 0.0)
    for e in incs:
        eng.run_increment(e, max_cycles=500_000)
    allv = np.concatenate(incs)
    np.testing.assert_array_equal(eng.values(128), bfs_levels(128, allv, 0))


def test_rmat_degrees_are_skewed():
    spec = StreamSpec(n_vertices=256, n_edges=4096, kind="rmat", seed=1)
    e = rmat_edges(spec)
    assert len(e) == 4096
    assert e.min() >= 0 and e.max() < 256
    deg = np.bincount(e[:, 0], minlength=256)
    # power-law: the top vertex dwarfs the median degree
    assert deg.max() >= 8 * max(1, int(np.median(deg)))


def test_rhizome_beats_chain_on_skewed_stream():
    """Acceptance: max degree >= 8x edge_cap -> rhizome_cap=4 reaches
    quiescence in fewer cycles than the serial chain (rhizome_cap=1)."""
    n = 64
    e2 = hub_edges(n, hub=0, degree=8 * 4 * 2, seed=13)  # 8x edge_cap=8
    edges = with_weights(e2)
    cycles = {}
    for R in (1, 4):
        eng = StreamingEngine(cfg_for(R, edge_cap=8, ghost_slots=48), "bfs")
        eng.seed(0, 0.0)
        r = eng.run_increment(edges, max_cycles=500_000)
        np.testing.assert_array_equal(eng.values(n), bfs_levels(n, edges, 0))
        cycles[R] = r.cycles
    assert cycles[4] < cycles[1], cycles


def test_load_stream_spill_residue():
    """io_stream_cap overflow must spill and re-load, not assert."""
    n = 32
    rng = np.random.default_rng(17)
    src = rng.integers(0, n, 600)
    dst = rng.integers(0, n, 600)
    ok = src != dst
    edges = with_weights(np.stack([src[ok], dst[ok]], 1))
    cfg = cfg_for(1, n_vertices=n, io_stream_cap=16, ghost_slots=48)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    eng.run_increment(edges, max_cycles=500_000)
    total = int(np.asarray(eng.state.nedges).sum())
    assert total == len(edges)
    np.testing.assert_array_equal(eng.values(n), bfs_levels(n, edges, 0))
