"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs.  All 10 assigned archs
plus the paper's CCA workload are covered via the registry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import shape
from repro.configs.registry import ARCHS

LM_ARCHS = [a for a, b in ARCHS.items() if b.family == "lm"]
GNN_ARCHS = [a for a, b in ARCHS.items() if b.family == "gnn"]


def _grad_step(loss_fn, params, batch):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                       params, grads)
    return loss, new


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models.transformer import (init_lm_params, lm_decode_step,
                                          lm_forward, lm_loss,
                                          init_kv_cache)
    cfg = ARCHS[arch].smoke()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    logits, aux = jax.jit(lambda p, t: lm_forward(cfg, p, t))(params, toks)
    assert logits.shape == (B, T, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()
    # train step
    batch = dict(tokens=toks, targets=jnp.roll(toks, -1, 1))
    loss, params2 = _grad_step(
        lambda p, b: lm_loss(cfg, p, b), params, batch)
    assert np.isfinite(float(loss))
    # decode step with kv cache
    cache = init_kv_cache(cfg, B, 64)
    lengths = jnp.full((B,), T, jnp.int32)
    # prefill cache by stepping tokens one by one for 2 steps
    lg, cache = jax.jit(
        lambda p, t, c, l: lm_decode_step(cfg, p, t, c, l)
    )(params, toks[:, :1], cache, jnp.zeros((B,), jnp.int32))
    assert lg.shape == (B, 1, cfg.vocab)
    assert not np.isnan(np.asarray(lg)).any()


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    from repro.data.graphs import build_graph
    from repro.models.gnn import gnn_forward, gnn_loss, init_gnn_params
    cfg = ARCHS[arch].smoke()
    spec = shape("smoke", "gnn_full", n_nodes=64, n_edges=256, d_feat=cfg.d_in)
    g = build_graph(cfg, spec)
    params = init_gnn_params(cfg, jax.random.PRNGKey(0))
    out = jax.jit(lambda p, g: gnn_forward(cfg, p, g))(params, g)
    assert out.shape == (64, cfg.d_out)
    assert not np.isnan(np.asarray(out)).any()
    labels = jnp.zeros((64,), jnp.int32)
    mask = jnp.ones((64,), jnp.float32)
    loss, _ = _grad_step(
        lambda p, b: gnn_loss(cfg, p, b), params,
        dict(graph=g, labels=labels, mask=mask))
    assert np.isfinite(float(loss))


def test_dlrm_smoke():
    from repro.data.pipeline import RecSysBatchSpec, recsys_batch
    from repro.models.dlrm import (dlrm_forward, dlrm_loss,
                                   init_dlrm_params, retrieval_score)
    cfg = ARCHS["dlrm-rm2"].smoke()
    params = init_dlrm_params(cfg, jax.random.PRNGKey(0))
    spec = RecSysBatchSpec(batch=16, n_dense=cfg.n_dense,
                           n_sparse=cfg.n_sparse,
                           lookups=cfg.lookups_per_field,
                           vocab_sizes=cfg.resolved_vocabs())
    batch = recsys_batch(spec, 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    logits = jax.jit(lambda p, b: dlrm_forward(cfg, p, b))(params, batch)
    assert logits.shape == (16,)
    assert not np.isnan(np.asarray(logits)).any()
    loss, _ = _grad_step(lambda p, b: dlrm_loss(cfg, p, b), params, batch)
    assert np.isfinite(float(loss))
    # retrieval scoring
    batch1 = {k: v[:1] for k, v in batch.items()}
    batch1["candidates"] = jax.random.normal(
        jax.random.PRNGKey(2), (256, cfg.bot_mlp[-1]))
    scores, ids = jax.jit(
        lambda p, b: retrieval_score(cfg, p, b))(params, batch1)
    assert scores.shape == (1, 100) and ids.shape == (1, 100)


def test_cca_smoke():
    from repro.core import StreamingEngine
    from repro.core.reference import bfs_levels
    cfg = ARCHS["cca-streaming-bfs"].smoke()
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    one = np.float32(1.0).view(np.int32)
    edges = np.array([(i, i + 1, one) for i in range(8)], np.int32)
    eng.run_increment(edges, max_cycles=5000)
    want = bfs_levels(cfg.n_vertices, edges, 0)
    np.testing.assert_array_equal(eng.values(), want)


def test_registry_covers_assignment():
    assigned = {"phi3.5-moe-42b-a6.6b", "arctic-480b", "starcoder2-3b",
                "qwen3-1.7b", "llama3.2-1b", "gatedgcn", "gcn-cora",
                "graphcast", "meshgraphnet", "dlrm-rm2"}
    assert assigned <= set(ARCHS)
    # 4 shapes per assigned arch -> 40 cells (+ paper's own)
    n_cells = sum(len(ARCHS[a].shapes) for a in assigned)
    assert n_cells == 40


def test_param_counts_match_public_sizes():
    """Sanity: analytic parameter counts are in the published ballpark."""
    lm = {a: ARCHS[a].config for a in LM_ARCHS}
    total = {a: c.n_params() / 1e9 for a, c in lm.items()}
    active = {a: c.n_active_params() / 1e9 for a, c in lm.items()}
    assert 35 <= total["phi3.5-moe-42b-a6.6b"] <= 50      # ~42B
    assert 5 <= active["phi3.5-moe-42b-a6.6b"] <= 8       # ~6.6B
    assert 400 <= total["arctic-480b"] <= 560             # ~480B
    assert 2.4 <= total["starcoder2-3b"] <= 3.6
    assert 1.2 <= total["qwen3-1.7b"] <= 2.4              # 1.7B (tied emb)
    assert 0.9 <= total["llama3.2-1b"] <= 1.8
