"""--arch meshgraphnet (exact published config; see gnn_archs.py)."""
from repro.configs.gnn_archs import MESHGRAPHNET as CONFIG
from repro.configs.registry import get

BUNDLE = get("meshgraphnet")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
