"""Step builders: per (arch x shape) jittable step functions + abstract
input specs (ShapeDtypeStruct — no allocation) + shardings.

This is the single source of truth used by the dry-run, the roofline
analysis, and the end-to-end drivers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchBundle, ShapeSpec
from repro.dist import ctx as dist_ctx
from repro.dist.sharding import (cca_state_shardings, dlrm_batch_shardings,
                                 dlrm_param_shardings, gnn_axes,
                                 gnn_graph_shardings, gnn_param_shardings,
                                 lm_batch_shardings, lm_cache_shardings,
                                 lm_param_shardings, pad_to)
from repro.launch.mesh import dp_axes
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw


class StepPlan(NamedTuple):
    step: callable
    args: tuple          # ShapeDtypeStruct pytrees
    in_shardings: tuple
    donate: tuple = ()
    static_desc: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _rep(mesh, tree):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, P(*((None,) * l.ndim))), tree)


# ------------------------------------------------------------------ LM ---

def _lm_cfg(bundle, overrides):
    cfg = bundle.config
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_impl="ep")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def _lm_train_plan(bundle: ArchBundle, spec: ShapeSpec, mesh,
                   overrides=None) -> StepPlan:
    from repro.models.transformer import init_lm_params, lm_loss
    cfg = _lm_cfg(bundle, overrides)
    B = spec.dim("global_batch")
    T = spec.dim("seq_len")
    opt_cfg = AdamWConfig()

    params_shape = jax.eval_shape(
        functools.partial(init_lm_params, cfg), jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(init_adamw, params_shape)
    batch = dict(tokens=_sds((B, T), jnp.int32),
                 targets=_sds((B, T), jnp.int32))

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch))(params)
        params, opt, gnorm = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, loss, gnorm

    pshard = lm_param_shardings(mesh, params_shape)
    oshard = type(opt_shape)(
        step=NamedSharding(mesh, P()), m=pshard, v=pshard)
    return StepPlan(step, (params_shape, opt_shape, batch),
                    (pshard, oshard, lm_batch_shardings(mesh)),
                    donate=(0, 1), static_desc=f"{cfg.name} train B{B} T{T}")


def _lm_prefill_plan(bundle, spec, mesh, overrides=None) -> StepPlan:
    from repro.models.transformer import init_lm_params, lm_forward
    cfg = _lm_cfg(bundle, overrides)
    B, T = spec.dim("global_batch"), spec.dim("seq_len")
    params_shape = jax.eval_shape(
        functools.partial(init_lm_params, cfg), jax.random.PRNGKey(0))
    batch = dict(tokens=_sds((B, T), jnp.int32))

    def step(params, batch):
        logits, _ = lm_forward(cfg, params, batch["tokens"])
        return logits[:, -1, :]  # serving returns last-token logits

    return StepPlan(step, (params_shape, batch),
                    (lm_param_shardings(mesh, params_shape),
                     dict(tokens=NamedSharding(mesh, P(dp_axes(mesh), None)))),
                    static_desc=f"{cfg.name} prefill B{B} T{T}")


def _lm_decode_plan(bundle, spec, mesh, overrides=None) -> StepPlan:
    from repro.models.transformer import (init_kv_cache, init_lm_params,
                                          lm_decode_step)
    cfg = dataclasses.replace(_lm_cfg(bundle, overrides), remat=False)
    B, T = spec.dim("global_batch"), spec.dim("seq_len")
    params_shape = jax.eval_shape(
        functools.partial(init_lm_params, cfg), jax.random.PRNGKey(0))
    cache_shape = jax.eval_shape(
        functools.partial(init_kv_cache, cfg, B, T))
    toks = _sds((B, 1), jnp.int32)
    lens = _sds((B,), jnp.int32)

    def step(params, tokens, cache, lengths):
        logits, cache = lm_decode_step(cfg, params, tokens, cache, lengths)
        return logits, cache

    dp = dp_axes(mesh)
    tok_spec = NamedSharding(mesh, P(dp, None)) if B > 1 else \
        NamedSharding(mesh, P(None, None))
    len_spec = NamedSharding(mesh, P(dp)) if B > 1 else \
        NamedSharding(mesh, P(None))
    return StepPlan(
        step, (params_shape, toks, cache_shape, lens),
        (lm_param_shardings(mesh, params_shape), tok_spec,
         lm_cache_shardings(mesh, cfg, B), len_spec),
        donate=(2,), static_desc=f"{cfg.name} decode B{B} KV{T}")


# ----------------------------------------------------------------- GNN ---

def _gnn_graph_specs(cfg, spec, mesh):
    """Padded abstract Graph + labels/mask for a shape spec."""
    from repro.data.graphs import graphcast_sizes, sampled_subgraph_sizes
    from repro.models.gnn import Graph
    d = dict(spec.dims)
    mult = int(np.prod([mesh.shape[a] for a in gnn_axes(mesh)]))
    if spec.kind == "gnn_minibatch":
        n, e = sampled_subgraph_sizes(d)
    elif spec.kind == "gnn_batched":
        n, e = d["batch"] * d["n_nodes"], d["batch"] * d["n_edges"]
    else:
        n, e = d["n_nodes"], d["n_edges"]
    n, e = pad_to(n, mult), pad_to(e, mult)
    cfg = dataclasses.replace(cfg, d_in=d["d_feat"])
    fields = dict(x=_sds((n, d["d_feat"]), jnp.float32),
                  edge_index=_sds((2, e), jnp.int32))
    if cfg.kind == "graphcast":
        gs = graphcast_sizes(cfg, n)
        fields.update(
            mesh_edge_index=_sds((2, pad_to(gs["e_mesh"], mult)), jnp.int32),
            g2m_edge_index=_sds((2, pad_to(gs["e_g2m"], mult)), jnp.int32),
            m2g_edge_index=_sds((2, pad_to(gs["e_m2g"], mult)), jnp.int32))
    graph = Graph(**fields)
    return cfg, graph, n


def _gnn_train_plan(bundle, spec, mesh) -> StepPlan:
    from repro.models.gnn import gnn_loss, init_gnn_params
    cfg, graph, n = _gnn_graph_specs(bundle.config, spec, mesh)
    regression = cfg.kind in ("graphcast", "meshgraphnet")
    labels = _sds((n, cfg.d_out), jnp.float32) if regression \
        else _sds((n,), jnp.int32)
    mask = _sds((n,), jnp.float32)
    params_shape = jax.eval_shape(
        functools.partial(init_gnn_params, cfg), jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt_shape = jax.eval_shape(init_adamw, params_shape)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn_loss(cfg, p, batch))(params)
        params, opt, gnorm = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, loss, gnorm

    ax = gnn_axes(mesh)
    gshard = type(graph)(**{
        **{k: None for k in graph._fields},
        **gnn_graph_shardings(mesh, graph._asdict())})
    bshard = dict(graph=gshard,
                  labels=NamedSharding(mesh, P(ax, None)) if regression
                  else NamedSharding(mesh, P(ax)),
                  mask=NamedSharding(mesh, P(ax)))
    pshard = gnn_param_shardings(mesh, params_shape)
    oshard = type(opt_shape)(step=NamedSharding(mesh, P()),
                             m=pshard, v=pshard)
    batch = dict(graph=graph, labels=labels, mask=mask)
    return StepPlan(step, (params_shape, opt_shape, batch),
                    (pshard, oshard, bshard), donate=(0, 1),
                    static_desc=f"{cfg.name} {spec.name} N{n}")


# -------------------------------------------------------------- RecSys ---

def _dlrm_plan(bundle, spec, mesh) -> StepPlan:
    from repro.models.dlrm import (dlrm_forward, dlrm_loss,
                                   init_dlrm_params, retrieval_score)
    cfg = bundle.config
    B = spec.dim("batch")
    L = cfg.lookups_per_field
    params_shape = jax.eval_shape(
        functools.partial(init_dlrm_params, cfg), jax.random.PRNGKey(0))
    batch = dict(dense=_sds((B, cfg.n_dense), jnp.float32),
                 sparse=_sds((B, cfg.n_sparse, L), jnp.int32),
                 labels=_sds((B,), jnp.int32))
    pshard = dlrm_param_shardings(mesh, params_shape)

    if spec.kind == "recsys_train":
        opt_cfg = AdamWConfig()
        opt_shape = jax.eval_shape(init_adamw, params_shape)

        def step(params, opt, batch):
            loss, grads = jax.value_and_grad(
                lambda p: dlrm_loss(cfg, p, batch))(params)
            params, opt, gnorm = adamw_update(opt_cfg, grads, opt, params)
            return params, opt, loss, gnorm

        oshard = type(opt_shape)(step=NamedSharding(mesh, P()),
                                 m=pshard, v=pshard)
        return StepPlan(step, (params_shape, opt_shape, batch),
                        (pshard, oshard, dlrm_batch_shardings(mesh)),
                        donate=(0, 1), static_desc=f"dlrm train B{B}")

    if spec.kind == "recsys_serve":
        def step(params, batch):
            return dlrm_forward(cfg, params, batch)
        return StepPlan(step, (params_shape, batch),
                        (pshard, dlrm_batch_shardings(mesh)),
                        static_desc=f"dlrm serve B{B}")

    # retrieval: 1 query vs n_candidates
    C = pad_to(spec.dim("n_candidates"), 2048)
    batch = dict(dense=_sds((B, cfg.n_dense), jnp.float32),
                 sparse=_sds((B, cfg.n_sparse, L), jnp.int32),
                 labels=_sds((B,), jnp.int32),
                 candidates=_sds((C, cfg.bot_mlp[-1]), jnp.float32))

    def step(params, batch):
        return retrieval_score(cfg, params, batch)

    bshard = dlrm_batch_shardings(mesh, with_candidates=True)
    if B == 1:  # can't shard batch 1
        for k in ("dense", "sparse", "labels"):
            bshard[k] = _rep(mesh, batch[k])
    return StepPlan(step, (params_shape, batch), (pshard, bshard),
                    static_desc=f"dlrm retrieval C{C}")


# ----------------------------------------------------------------- CCA ---

def _cca_plan(bundle, spec, mesh) -> StepPlan:
    from repro.configs.cca_paper import engine_config_for
    from repro.core.apps import BFS
    from repro.core.engine import run_chunk_body
    from repro.core.state import init_state
    ecfg = dataclasses.replace(engine_config_for(spec), chunk=8)
    state_shape = jax.eval_shape(functools.partial(init_state, ecfg))

    def step(state):
        return run_chunk_body(ecfg, BFS, state)

    sshard = cca_state_shardings(mesh, state_shape)
    return StepPlan(step, (state_shape,), (sshard,), donate=(0,),
                    static_desc=f"cca {ecfg.height}x{ecfg.width} "
                                f"x{ecfg.chunk}cyc")


# ------------------------------------------------------------- dispatch --

def build_plan(bundle: ArchBundle, spec: ShapeSpec, mesh,
               lm_overrides=None) -> StepPlan:
    dist_ctx.set_dist_mesh(mesh)
    kind = spec.kind
    if kind == "lm_train":
        return _lm_train_plan(bundle, spec, mesh, lm_overrides)
    if kind == "lm_prefill":
        return _lm_prefill_plan(bundle, spec, mesh, lm_overrides)
    if kind == "lm_decode":
        return _lm_decode_plan(bundle, spec, mesh, lm_overrides)
    if kind.startswith("gnn"):
        return _gnn_train_plan(bundle, spec, mesh)
    if kind.startswith("recsys"):
        return _dlrm_plan(bundle, spec, mesh)
    if kind == "cca_stream":
        return _cca_plan(bundle, spec, mesh)
    raise ValueError(kind)
