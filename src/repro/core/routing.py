"""YX dimension-ordered routing on the cell mesh (paper §4).

Messages take vertical (row) hops first, then horizontal — the
turn-restricted, minimal-path, deadlock-free YX variant of [Glass & Ni'92]
cited by the paper.  One hop per cycle per link (256-bit flit).

The hop stage is written as masked ``jnp.roll`` over the ``[H, W]`` grid.
Under pjit/GSPMD with the grid sharded over mesh axes this lowers to
``collective-permute`` at tile boundaries — the TPU ICI plays the role of
the AM-CCA mesh links (DESIGN §2).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import EngineConfig
from repro.core.msg import (DIR_E, DIR_N, DIR_S, DIR_W, N_DIRS, OP_ALLOC,
                            OP_LINK_RHIZOME, OP_RHIZOME_FWD, OP_SET_FUTURE,
                            TB_AQ_SELF, TB_CHAN_E, TB_CHAN_N, TB_CHAN_S,
                            TB_CHAN_W)
from repro.core import rings
from repro.core.state import MachineState


def manhattan_hops(cfg: EngineConfig, dst_cell, rows, cols):
    """YX-DOR path length (Manhattan hops) from cell (rows, cols) to
    ``dst_cell``.  Shapes broadcast; the routing-distance metric used by IO
    cells to pick the *nearest* rhizome root of a vertex (DESIGN §4.5)."""
    dr = dst_cell // cfg.width
    dc = dst_cell % cfg.width
    return jnp.abs(dr - rows) + jnp.abs(dc - cols)


def yx_target_buffer(cfg: EngineConfig, dst_cell, rows, cols):
    """Next-buffer code for a message sitting at cell (rows, cols).

    Vertical first, then horizontal, deliver locally when arrived.
    Shapes broadcast; returns int32 target-buffer codes (TB_*).
    """
    dr = dst_cell // cfg.width
    dc = dst_cell % cfg.width
    vert = jnp.where(dr < rows, TB_CHAN_N, TB_CHAN_S)
    horiz = jnp.where(dc < cols, TB_CHAN_W, TB_CHAN_E)
    out = jnp.where(dr != rows, vert, jnp.where(dc != cols, horiz, TB_AQ_SELF))
    return out.astype(jnp.int32)


def deliver(cfg: EngineConfig, aq, aq_n, aq_head, ch, ch_n, ch_head,
            msg, tb, want, aq_room):
    """Shape-polymorphic buffer admission: place ``msg`` into the local
    action queue (``tb == TB_AQ_SELF``) or one of the four outgoing
    channels (``tb == TB_CHAN_*``) of the cell it currently sits at.

    All operands share arbitrary leading batch dims ``*B`` — the full
    ``[H, W]`` grid in the hop/staging stages (jnp path and the Pallas
    cycle megakernel alike), the ``[W]`` row-0 slice in the IO stage::

        aq [*B,Q,MSG]  aq_n/aq_head [*B]   ch [*B,4,C,MSG]
        ch_n/ch_head [*B,4]  msg [*B,MSG]  tb/want/aq_room [*B]

    ``aq_room`` is the caller's action-queue admission predicate (every
    stage applies a different reserve rule — DESIGN §4.2); channel
    admission is plain ``ring_free``.  Returns the updated buffers and
    the acceptance mask; where ``want & ~ok`` the message stays with the
    caller (wormhole-style backpressure stall).
    """
    ok_aq = want & (tb == TB_AQ_SELF) & aq_room
    aq, aq_n = rings.ring_push(aq, aq_n, aq_head, msg, ok_aq)
    ok_all = ok_aq
    for d in range(N_DIRS):
        ok = want & (tb == d) & rings.ring_free(ch_n[..., d], cfg.chan_cap)
        nb, nn = rings.ring_push(ch[..., d, :, :], ch_n[..., d],
                                 ch_head[..., d], msg, ok)
        ch = ch.at[..., d, :, :].set(nb)
        ch_n = ch_n.at[..., d].set(nn)
        ok_all = ok_all | ok
    return aq, aq_n, ch, ch_n, ok_all


# direction -> (row shift, col shift) that moves a message ALONG d.
_SHIFT = {DIR_N: (-1, 0), DIR_S: (1, 0), DIR_W: (0, -1), DIR_E: (0, 1)}


def shift_to_receiver(arr, d):
    """Move per-sender values [H,W,...] so they align with the receiving cell.

    A message leaving (r,c) northwards arrives at (r-1,c): roll by -1 on
    rows.  Mesh (non-torus): wrapped lanes are masked by the caller using
    `valid_receiver_mask`.
    """
    dy, dx = _SHIFT[d]
    a = arr
    if dy:
        a = jnp.roll(a, dy, axis=0)
    if dx:
        a = jnp.roll(a, dx, axis=1)
    return a


def shift_to_sender(arr, d):
    """Inverse of shift_to_receiver (align acceptance back to the sender)."""
    dy, dx = _SHIFT[d]
    a = arr
    if dy:
        a = jnp.roll(a, -dy, axis=0)
    if dx:
        a = jnp.roll(a, -dx, axis=1)
    return a


def valid_receiver_mask(cfg: EngineConfig, d):
    """[H,W] bool: True where a received-from-direction-d slot is real
    (i.e. not a torus wrap-around artifact of jnp.roll)."""
    H, W = cfg.height, cfg.width
    r = jnp.arange(H)[:, None]
    c = jnp.arange(W)[None, :]
    if d == DIR_N:
        m = r < H - 1   # receiver row r gets from sender row r+1... see note
    elif d == DIR_S:
        m = r > 0
    elif d == DIR_W:
        m = c < W - 1
    else:
        m = c > 0
    return jnp.broadcast_to(m, (H, W))


def hop_stage(cfg: EngineConfig, st: MachineState, rows, cols):
    """One routing cycle: the head of every occupied channel tries to hop
    one link.  At the receiver it is delivered to the action queue (if it
    arrived) or appended to the proper outgoing channel per YX order.
    Full buffers exert backpressure: the head simply stays (wormhole-style
    stall); YX dimension order keeps this deadlock-free.

    Links are arbitrated in fixed direction order N,S,W,E so multiple
    arrivals at one cell in the same cycle are sequenced deterministically.
    Returns (state, hops_this_cycle).
    """
    Q, C = cfg.queue_cap, cfg.chan_cap
    hops = jnp.int32(0)
    aq, aq_n, aq_head = st.aq, st.aq_n, st.aq_head
    ch, ch_n, ch_head = st.ch, st.ch_n, st.ch_head

    for d in (DIR_N, DIR_S, DIR_W, DIR_E):
        # head message of every cell's outgoing channel d
        head_msg = rings.ring_peek(ch[:, :, d], ch_head[:, :, d])  # [H,W,MSG]
        occupied = ch_n[:, :, d] > 0
        # align with receiver
        msg_r = shift_to_receiver(head_msg, d)
        occ_r = shift_to_receiver(occupied, d) & valid_receiver_mask(cfg, d)
        dst_cell = msg_r[..., 1] // cfg.slots
        tb = yx_target_buffer(cfg, dst_cell, rows, cols)       # [H,W]
        # AQ admission rule: external pushes respect the local-emission
        # reserve; system actions (allocate / set-future) additionally get
        # the sys_reserve headroom so the future protocol always advances.
        # OP_RHIZOME_FWD doubles as the link-ack that drains deferred
        # inserts at a pending rhizome root — like SET_FUTURE it must be
        # able to enter a queue that is closed to application messages.
        is_sys = ((msg_r[..., 0] == OP_ALLOC)
                  | (msg_r[..., 0] == OP_SET_FUTURE)
                  | (msg_r[..., 0] == OP_LINK_RHIZOME)
                  | (msg_r[..., 0] == OP_RHIZOME_FWD))
        room = jnp.where(is_sys,
                         rings.ring_free(aq_n, Q, cfg.aq_reserve),
                         rings.ring_free(aq_n, Q,
                                         cfg.aq_reserve + cfg.sys_reserve))
        aq, aq_n, ch, ch_n, accepted_r = deliver(
            cfg, aq, aq_n, aq_head, ch, ch_n, ch_head,
            msg_r, tb, occ_r, room)
        hops = hops + jnp.sum(accepted_r.astype(jnp.int32))
        # pop at the sender where the hop succeeded
        acc_s = shift_to_sender(accepted_r, d)
        n2, h2 = rings.ring_pop(ch_n[:, :, d], ch_head[:, :, d], C, acc_s)
        ch_n = ch_n.at[:, :, d].set(n2)
        ch_head = ch_head.at[:, :, d].set(h2)

    return st._replace(aq=aq, aq_n=aq_n, ch=ch, ch_n=ch_n, ch_head=ch_head), hops
