"""--arch graphcast (exact published config; see gnn_archs.py)."""
from repro.configs.gnn_archs import GRAPHCAST as CONFIG
from repro.configs.registry import get

BUNDLE = get("graphcast")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
