"""Gradient compression for the slow inter-pod (DCN) axis.

int8 block-quantization with **error feedback** (residual carried to the
next step) — the standard trick that keeps compressed SGD/Adam convergent
(1-bit Adam / EF-SGD lineage).  Used by the trainer to compress gradients
before the inter-pod all-reduce: 4x fewer DCN bytes; ICI reductions stay
full precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, block=256):
    """x: any float array -> (q int8, scale f32 per block, pad)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad


def dequantize_int8(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_with_feedback(grads, residuals, block=256):
    """Returns (compressed repr, new residuals).

    residuals: pytree like grads (running quantization error).
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s, pad = quantize_int8(gf, block)
        deq = dequantize_int8(q, s, pad, gf.shape)
        return (q, s, pad), gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = tdef.unflatten([o[0] for o in outs])
    new_res = tdef.unflatten([o[1] for o in outs])
    return comp, new_res


def decompress(comp, grads_like):
    def one(c, g):
        q, s, pad = c
        return dequantize_int8(q, s, pad, g.shape).astype(g.dtype)
    flat_c, tdef = jax.tree.flatten(grads_like)
    flat = tdef.flatten_up_to(comp)
    return tdef.unflatten([one(c, g)
                           for c, g in zip(flat, flat_c)])


def init_residuals(grads_like):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
