"""Jitted EmbeddingBag wrapper."""
from __future__ import annotations

import functools

import jax

from repro.kernels.embedding_bag.kernel import embedding_bag_fwd
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@functools.partial(jax.jit, static_argnames=("combiner", "interpret"))
def embedding_bag(table, indices, *, combiner="sum", interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return embedding_bag_fwd(table, indices, combiner=combiner,
                             interpret=interpret)


embedding_bag_reference = jax.jit(embedding_bag_ref,
                                  static_argnames=("combiner",))
