"""FrontDesk — tenant admission control + per-query latency accounting.

Pending queries queue here and are admitted into free MQSession slots at
increment boundaries, gated on the ``tm_hiw`` action-queue hi-water mark
(DESIGN §8/§9): when the last increment drove any cell's queue above the
admission ceiling, new tenants wait — the same backpressure philosophy as
the ingest guard, applied to query load instead of edge load.  With
telemetry off the gate is open (free slots are the only limit).

Retired tenants leave a receipt; ``latency_report`` folds the receipts'
time-to-quiescence into the standard ``repro.obs.metrics`` percentile
summary (p50/p90/p99, cycles).
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core.state import TM_HW_AQ
from repro.mq.session import MQSession
from repro.obs import metrics


@dataclasses.dataclass
class QueryRequest:
    app: str
    source: int
    submitted_pos: int = 0       # increment index at submission


class FrontDesk:
    """Admission queue in front of an :class:`MQSession`."""

    def __init__(self, session: MQSession, hiw_frac: float = 0.75):
        self.session = session
        self.hiw_frac = hiw_frac
        self.pending: "collections.deque[QueryRequest]" = collections.deque()
        self.receipts: "list[dict]" = []
        self.pos = 0                 # increments pumped
        self.deferrals = 0           # admissions delayed by the hiw gate

    # ---------------- intake ----------------

    def submit(self, app: str, source: int) -> QueryRequest:
        req = QueryRequest(app=app, source=source, submitted_pos=self.pos)
        self.pending.append(req)
        return req

    def admission_open(self) -> bool:
        """tm_hiw gate: admit only while the last increment's worst
        action-queue hi-water stayed under ``hiw_frac`` of the usable
        depth (cap minus the §4.2 reserves)."""
        cfg = self.session.eng.cfg
        if not cfg.telemetry:
            return True
        hiw = int(np.asarray(
            self.session.eng.state.tm_hiw[..., TM_HW_AQ]).max())
        ceiling = cfg.queue_cap - cfg.aq_reserve - cfg.sys_reserve
        return hiw < self.hiw_frac * ceiling

    # ---------------- the serving loop ----------------

    def pump(self) -> "list[int]":
        """Admit pending tenants into free slots (boundary only); returns
        the admitted slot indices."""
        admitted = []
        if self.pending and not self.admission_open():
            self.deferrals += len(self.pending)
            return admitted
        for q in self.session.free_slots():
            if not self.pending:
                break
            req = self.pending.popleft()
            admitted.append(self.session.admit(req.app, req.source))
        return admitted

    def step(self, edges, **kw):
        """One serving beat: admit, stream one increment, harvest settled
        tenants into receipts (freeing their slots)."""
        self.pump()
        res = self.session.run_increment(edges, **kw)
        self.pos += 1
        for q in self.session.settled_slots():
            self.receipts.append(self.session.retire(q))
        return res

    def drain(self, max_increments: int = 64, **kw):
        """Run empty increments until every tenant has settled and the
        pending queue is empty (end-of-stream flush)."""
        empty = np.zeros((0, 3), np.int32)
        for _ in range(max_increments):
            if not self.pending and not any(
                    s.state == "active" for s in self.session.slots):
                break
            self.step(empty, **kw)

    # ---------------- reporting ----------------

    def latency_report(self) -> dict:
        """Percentile summary (repro.obs.metrics) of per-query
        time-to-quiescence, in machine cycles."""
        lat = [r["latency_cycles"] for r in self.receipts
               if r["latency_cycles"] is not None]
        out = metrics.summarize(lat, unit="cycles")
        out["deferrals"] = self.deferrals
        out["served"] = len(self.receipts)
        return out
