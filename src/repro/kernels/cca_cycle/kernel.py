"""Fused Pallas cycle megakernel: K engine cycles per launch with the
whole ``MachineState`` resident on-chip (DESIGN §6).

The jnp chunk runners round-trip every state leaf through HBM once per
cycle — one scan/while iteration reads and writes megabytes of queues,
channels and vertex slots to produce a handful of mutated entries.  This
kernel is the Pallas analogue of the paper's scratchpad memory-coupled
CCA cells: every leaf is loaded into VMEM once per launch, ``K =
cfg.chunk`` cycles run inside a single ``fori_loop`` with the state
carried entirely on-chip, and the leaves are stored back once.  HBM
traffic per launch drops from ``K * |state|`` to ``|state|``.

Quiescence (the paper's Terminator object) is tested in-kernel every
cycle; once reached the remaining iterations freeze to the identity, so
a launch never overshoots the quiescent state and the final ``cycle``
counter is the exact quiescence cycle — this is what makes the Pallas
backend bit-exact against the jnp backend's early-exit ``while_loop``
(pinned by tests/test_cycle_kernel.py).  The quiescence/progress
counters accumulate in an SMEM scalar record (layout in ``ops.py``).

The cycle semantics are imported, not re-implemented: the kernel body
wraps ``ref.frozen_cycles`` — the exact function the reference path
runs — between its loads and stores, so the two backends cannot drift.
Off-TPU the kernel runs with ``interpret=True`` (CI) — see ``ops.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.state import MachineState
from repro.kernels.cca_cycle.ref import frozen_cycles

# SMEM scalar-record layout: one (1, 8) int32 row per launch.
SCALAR_LEAVES = ("cycle", "stat_hops", "stat_exec", "stat_stall",
                 "stat_allocs")
IDX_QUIESCENT = 5   # machine quiescent at end of launch
IDX_RAN = 6         # non-frozen cycles executed this launch
N_SCALARS = 8
# leaves stored as int32 on the wire (Mosaic prefers int over i1 arrays)
BOOL_LEAVES = frozenset({"cvalid", "fwd_pending", "rhz_on"})


def cycle_megakernel(cfg, app, n_cycles, names, *refs):
    """Pallas kernel body.  ``refs`` is ``(scal_in, *arr_in, scal_out,
    *arr_out)`` with every input aliased onto the matching output; the
    array refs follow ``names`` (the non-scalar ``MachineState`` fields
    in declaration order)."""
    n_in = len(refs) // 2
    scal_in, arr_in = refs[0], refs[1:n_in]
    scal_out, arr_out = refs[n_in], refs[n_in + 1:]

    # ---- load: HBM/VMEM blocks -> on-chip values, rebuild the pytree ----
    leaves = {}
    for name, ref in zip(names, arr_in):
        v = ref[...]
        leaves[name] = (v != 0) if name in BOOL_LEAVES else v
    for i, name in enumerate(SCALAR_LEAVES):
        leaves[name] = scal_in[0, i]
    st = MachineState(**leaves)

    # ---- compute: K fused cycles, state carried on-chip ----
    st, q, ran = frozen_cycles(cfg, app, st, n_cycles)

    # ---- store: single write-back per leaf + SMEM counters ----
    for name, ref in zip(names, arr_out):
        v = getattr(st, name)
        ref[...] = v.astype(jnp.int32) if name in BOOL_LEAVES else v
    for i, name in enumerate(SCALAR_LEAVES):
        scal_out[0, i] = getattr(st, name)
    scal_out[0, IDX_QUIESCENT] = q.astype(scal_out.dtype)
    scal_out[0, IDX_RAN] = ran
    scal_out[0, N_SCALARS - 1] = 0
