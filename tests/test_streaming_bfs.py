"""End-to-end behaviour tests for the paper's system: streaming dynamic
graph construction + incremental BFS, verified against NetworkX (paper §4).
"""
import numpy as np
import pytest

from repro.core import EngineConfig, StreamingEngine
from repro.core.reference import bfs_levels, cc_labels, sssp_dists
from repro.graph.streams import StreamSpec, make_stream

ONE = np.float32(1.0).view(np.int32)


def small_cfg(**kw):
    base = dict(height=8, width=8, n_vertices=256, edge_cap=4,
                ghost_slots=32, queue_cap=32, chan_cap=8, futq_cap=8,
                io_stream_cap=2048, chunk=128)
    base.update(kw)
    return EngineConfig(**base)


def run_stream(cfg, incs, app="bfs", seed_vertex=0, seed_val=0.0):
    eng = StreamingEngine(cfg, app)
    if app != "ingest_only":
        eng.seed(seed_vertex, seed_val)
    results = [eng.run_increment(e, max_cycles=500_000) for e in incs]
    return eng, results


@pytest.mark.parametrize("sampling", ["edge", "snowball"])
@pytest.mark.parametrize("allocator", ["vicinity", "random"])
def test_streaming_bfs_matches_networkx(sampling, allocator):
    spec = StreamSpec(n_vertices=256, n_edges=2048, increments=5,
                      sampling=sampling, seed=3)
    incs = make_stream(spec)
    cfg = small_cfg(allocator=allocator)
    eng, results = run_stream(cfg, incs)
    all_edges = np.concatenate(incs)
    want = bfs_levels(256, all_edges, 0)
    got = eng.values(256)
    np.testing.assert_array_equal(got, want)
    assert all(r.cycles > 0 for r in results)


def test_incremental_no_recompute_property():
    """After each increment the levels must equal BFS on the prefix —
    the paper's central claim: results update without recomputation."""
    spec = StreamSpec(n_vertices=128, n_edges=768, increments=4, seed=7)
    incs = make_stream(spec)
    cfg = small_cfg(n_vertices=128)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    prefix = []
    for e in incs:
        eng.run_increment(e, max_cycles=500_000)
        prefix.append(e)
        want = bfs_levels(128, np.concatenate(prefix), 0)
        np.testing.assert_array_equal(eng.values(128), want)


def test_ingestion_only_mode():
    """Paper §5: disabling bfs-action isolates pure streaming insertion."""
    spec = StreamSpec(n_vertices=128, n_edges=512, increments=2, seed=5)
    incs = make_stream(spec)
    cfg = small_cfg(n_vertices=128)
    eng, results = run_stream(cfg, incs, app="ingest_only")
    # no application values were touched
    assert (eng.values(128) == 1e9).all()
    # every edge was inserted exactly once: sum of nedges == total edges
    total = int(np.asarray(eng.state.nedges).sum())
    assert total == sum(len(e) for e in incs)
    # and ingestion-only takes fewer executed actions than ingestion+BFS
    eng2, _ = run_stream(cfg, incs, app="bfs")
    assert eng.totals["execs"] < eng2.totals["execs"] or \
        eng2.totals["execs"] == eng.totals["execs"]  # (BFS may not reach)


def test_streaming_sssp():
    rng = np.random.default_rng(11)
    n, m = 96, 512
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    ok = src != dst
    src, dst = src[ok], dst[ok]
    w = rng.integers(1, 9, len(src)).astype(np.float32)
    edges = np.stack([src, dst, w.view(np.int32)], axis=1).astype(np.int32)
    cfg = small_cfg(n_vertices=n)
    eng = StreamingEngine(cfg, "sssp")
    eng.seed(0, 0.0)
    # two increments
    eng.run_increment(edges[:len(edges) // 2], max_cycles=500_000)
    eng.run_increment(edges[len(edges) // 2:], max_cycles=500_000)
    want = sssp_dists(n, edges[:, :2], w, 0)
    np.testing.assert_allclose(eng.values(n), want, rtol=1e-6)


def test_streaming_connected_components():
    rng = np.random.default_rng(13)
    n, m = 128, 256
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    ok = src != dst
    e = np.stack([src[ok], dst[ok]], 1)
    # symmetric insertion for undirected CC
    e = np.concatenate([e, e[:, ::-1]], 0)
    edges = np.concatenate([e, np.full((len(e), 1), ONE)], 1).astype(np.int32)
    cfg = small_cfg(n_vertices=n)
    eng = StreamingEngine(cfg, "cc")
    # every vertex starts labeled with its own id
    import jax.numpy as jnp
    from repro.core.state import root_addr
    for v in range(n):
        eng.seed(v, float(v))
    eng.run_increment(edges, max_cycles=500_000)
    want = cc_labels(n, e)
    np.testing.assert_array_equal(eng.values(n), want)


def test_ghost_chain_spill_and_locality():
    """Hub vertex forces RPVO ghost chains; vicinity keeps them close."""
    n = 64
    hub_edges = [(0, i, ONE) for i in range(1, 41)]  # degree 40 >> edge_cap
    edges = np.array(hub_edges, np.int32)
    cfg = small_cfg(n_vertices=n, edge_cap=4, ghost_slots=16)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    eng.run_increment(edges, max_cycles=500_000)
    want = bfs_levels(n, edges, 0)
    np.testing.assert_array_equal(eng.values(n), want)
    stats = eng.vertex_object_stats()
    assert stats["ghosts"] >= 9  # ceil((40-4)/4) ghosts chained
    # vicinity: Chebyshev<=2 per hop allocation -> Manhattan <= 4 per link
    assert stats["max_hops"] <= 2 * cfg.vicinity_hops


def test_edge_conservation_under_ghosts():
    """No edge is lost or duplicated across the RPVO chain (property)."""
    spec = StreamSpec(n_vertices=64, n_edges=512, increments=3, seed=9)
    incs = make_stream(spec)
    cfg = small_cfg(n_vertices=64, edge_cap=2, ghost_slots=48, futq_cap=4)
    eng, _ = run_stream(cfg, incs)
    total = int(np.asarray(eng.state.nedges).sum())
    assert total == sum(len(e) for e in incs)


def test_backpressure_no_loss_small_buffers():
    """Small (but feasible) buffers: stalls must not lose messages."""
    spec = StreamSpec(n_vertices=64, n_edges=400, increments=2, seed=21)
    incs = make_stream(spec)
    cfg = small_cfg(n_vertices=64, edge_cap=2, ghost_slots=48,
                    queue_cap=16, chan_cap=8, futq_cap=2)
    eng, results = run_stream(cfg, incs)
    all_edges = np.concatenate(incs)
    want = bfs_levels(64, all_edges, 0)
    np.testing.assert_array_equal(eng.values(64), want)
    assert sum(r.stalls for r in results) > 0  # backpressure did engage


def test_livelock_detector_fires_below_min_sizing():
    """Buffers below the DESIGN §4.2 sizing rule close a protocol-level
    dependency cycle (message-dependent deadlock, beyond DOR's network
    guarantee).  The engine must detect it and fail loudly rather than
    lose work."""
    import pytest
    spec = StreamSpec(n_vertices=64, n_edges=400, increments=2, seed=21)
    incs = make_stream(spec)
    cfg = small_cfg(n_vertices=64, edge_cap=2, ghost_slots=48,
                    queue_cap=8, chan_cap=2, futq_cap=2)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    with pytest.raises(RuntimeError, match="livelock"):
        for e in incs:
            eng.run_increment(e, max_cycles=500_000)
