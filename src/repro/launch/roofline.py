"""Roofline-term extraction from compiled dry-run artifacts (DESIGN §7).

Terms, per device (cost_analysis on post-SPMD HLO is per-device — verified
by probe):

  compute    = HLO_FLOPs        / PEAK_FLOPS      (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes        / HBM_BW          (819 GB/s)
  collective = collective_bytes / LINK_BW         (~50 GB/s/link ICI)

collective_bytes is parsed from the compiled HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op we take max(operand bytes, result bytes) — single-link serialization,
a conservative upper bound.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals (per device)."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            # match '= <shape> kind(' and variants like all-reduce-start
            if f" {kind}(" in line or f" {kind}-start(" in line:
                shapes = [_shape_bytes(m)
                          for m in _SHAPE_RE.finditer(line)]
                if shapes:
                    out[kind] += max(shapes)
                    counts[kind] += 1
                break
    out["n_ops"] = sum(counts.values())
    out["counts"] = counts
    return out


def roofline_terms(flops: float, bytes_acc: float, coll: dict) -> dict:
    coll_bytes = sum(v for k, v in coll.items() if k in COLLECTIVES)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_acc / HBM_BW
    t_x = coll_bytes / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    return dict(t_compute=t_c, t_memory=t_m, t_collective=t_x,
                coll_bytes=coll_bytes, dominant=dom[1],
                bound_s=max(t_c, t_m, t_x),
                # fraction of the bound that is useful MXU work
                roofline_fraction=(t_c / max(t_c, t_m, t_x)
                                   if max(t_c, t_m, t_x) > 0 else 0.0))


# -------------------- analytic MODEL_FLOPS (global) --------------------

def model_flops(bundle, spec) -> float:
    """Paper-standard useful-FLOPs estimate for the whole step (global)."""
    fam, kind = bundle.family, spec.kind
    if fam == "lm":
        cfg = bundle.config
        n_act = cfg.n_active_params()
        B = spec.dim("global_batch")
        T = spec.dim("seq_len")
        if kind == "lm_train":
            return 6.0 * n_act * B * T
        if kind == "lm_prefill":
            return 2.0 * n_act * B * T
        # decode: one token + attention over the KV cache
        attn = 4.0 * B * T * cfg.n_heads * cfg.dh * cfg.n_layers
        return 2.0 * n_act * B + attn
    if fam == "gnn":
        cfg = bundle.config
        d = dict(spec.dims)
        if kind == "gnn_minibatch":
            from repro.data.graphs import sampled_subgraph_sizes
            n, e = sampled_subgraph_sizes(d)
        elif kind == "gnn_batched":
            n, e = d["batch"] * d["n_nodes"], d["batch"] * d["n_edges"]
        else:
            n, e = d["n_nodes"], d["n_edges"]
        dh, L = cfg.d_hidden, cfg.n_layers
        din = d.get("d_feat", cfg.d_in)
        if cfg.kind == "gcn":
            fwd = 2 * n * din * dh + 2 * (L - 1) * n * dh * dh \
                + 2 * L * e * dh
        elif cfg.kind == "gatedgcn":
            fwd = 2 * n * din * dh + L * (2 * (3 * e + 2 * n) * dh * dh
                                          + 8 * e * dh)
        elif cfg.kind == "meshgraphnet":
            fwd = 2 * n * din * dh + L * (8 * e * dh * dh
                                          + 6 * n * dh * dh)
        else:  # graphcast: processor on the multimesh + enc/dec blocks
            from repro.data.graphs import graphcast_sizes
            gs = graphcast_sizes(cfg, n)
            nm, em = gs["n_mesh"], gs["e_mesh"]
            fwd = (2 * n * din * dh
                   + 8 * (gs["e_g2m"] + gs["e_m2g"]) * dh * dh
                   + 6 * (n + nm) * dh * dh
                   + L * (8 * em * dh * dh + 6 * nm * dh * dh))
        return 3.0 * fwd  # train step: fwd + bwd
    if fam == "recsys":
        cfg = bundle.config
        B = spec.dim("batch")
        mlp = cfg.n_params() - sum(cfg.resolved_vocabs()) * cfg.embed_dim
        mult = 6.0 if kind == "recsys_train" else 2.0
        inter = 2.0 * B * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
        flop = mult * mlp * B + inter
        if kind == "recsys_retrieval":
            flop += 2.0 * spec.dim("n_candidates") * cfg.bot_mlp[-1]
        return flop
    return float("nan")  # cca: actions/cycle is the relevant metric
