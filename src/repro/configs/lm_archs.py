"""The five assigned LM-family transformer architectures (public configs)."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchBundle, lm_shapes
from repro.models.transformer import LMConfig

# phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]
PHI35_MOE = LMConfig(
    name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=6400, vocab=32064, n_experts=16, top_k=2,
    gated_ffn=True, norm="ln")

# arctic-480b [hf:Snowflake/snowflake-arctic-base]: 128e top-2 + dense residual
ARCTIC = LMConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, n_experts=128, top_k=2, dense_residual=True,
    gated_ffn=True, norm="rms")

# starcoder2-3b [arXiv:2402.19173]: GQA kv=2, RoPE, non-gated 4x FFN
STARCODER2_3B = LMConfig(
    name="starcoder2-3b", n_layers=30, d_model=3072, n_heads=24,
    n_kv_heads=2, d_ff=12288, vocab=49152, gated_ffn=False, norm="ln",
    rope_theta=1e5)

# qwen3-1.7b [hf:Qwen/Qwen3-*]: qk_norm, GQA kv=8, head_dim 128
QWEN3_1P7B = LMConfig(
    name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936, qk_norm=True, head_dim=128, gated_ffn=True,
    norm="rms", rope_theta=1e6)

# llama3.2-1b [hf:meta-llama/Llama-3.2-1B]
LLAMA32_1B = LMConfig(
    name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=128256, gated_ffn=True, norm="rms", rope_theta=5e5)


def _smoke(cfg: LMConfig) -> LMConfig:
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), d_ff=128, vocab=256,
        head_dim=16, n_experts=min(cfg.n_experts, 4), attn_chunk=32,
        remat=False)


def bundles():
    out = []
    for cfg in (PHI35_MOE, ARCTIC, STARCODER2_3B, QWEN3_1P7B, LLAMA32_1B):
        arch_id = {"phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b-a6.6b",
                   "arctic-480b": "arctic-480b",
                   "starcoder2-3b": "starcoder2-3b",
                   "qwen3-1.7b": "qwen3-1.7b",
                   "llama3.2-1b": "llama3.2-1b"}[cfg.name]
        out.append(ArchBundle(
            arch_id=arch_id, family="lm", config=cfg, shapes=lm_shapes(),
            smoke=(lambda c=cfg: _smoke(c)),
            notes="pure full-attention; long_500k run as sharded-KV decode"))
    return out
