"""Elasticity, failure handling and straggler mitigation (DESIGN §6).

On a real multi-pod deployment the runtime signals we handle are:
  * a worker disappears (ICI/DCN heartbeat loss)  -> restart from the last
    atomic checkpoint on a (possibly smaller) mesh — `plan_remesh` picks
    the largest valid mesh for the surviving chip count and
    Checkpointer.restore re-shards onto it (elastic restore);
  * a step exceeds the straggler deadline         -> StepWatchdog fires;
    the driver either re-dispatches the step (deterministic data makes
    the retry safe) or drops the slow replica for the next sync.

This module is exercised by tests/test_fault_tolerance.py: kill-restart
resume is bit-identical, and the watchdog triggers on injected delay.
"""
from __future__ import annotations

import dataclasses
import threading
import time


@dataclasses.dataclass
class MeshPlan:
    data: int
    model: int
    pods: int = 1

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods


def plan_remesh(surviving_devices: int, model_parallel: int = 16,
                pod_size: int = 256) -> MeshPlan:
    """Largest (pod, data, model) grid that fits the surviving chips,
    keeping TP intact (a TP group must be whole — losing one chip of a
    16-chip TP group costs the whole group)."""
    groups = surviving_devices // model_parallel
    if groups < 1:
        raise RuntimeError("fewer chips than one TP group survive")
    pods = max(1, surviving_devices // pod_size)
    data = groups // pods
    while pods > 1 and data < 1:
        pods -= 1
        data = groups // pods
    return MeshPlan(data=max(data, 1), model=model_parallel, pods=pods)


class StepWatchdog:
    """Detects stragglers: if a step doesn't complete within
    `deadline_s`, `on_straggler` fires (re-dispatch / drop-replica)."""

    def __init__(self, deadline_s: float, on_straggler=None):
        self.deadline = deadline_s
        self.on_straggler = on_straggler or (lambda step: None)
        self.fired = []
        self._timer = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.cancel()
        return False

    def arm(self, step: int):
        self.cancel()
        def fire():
            self.fired.append(step)
            self.on_straggler(step)
        self._timer = threading.Timer(self.deadline, fire)
        self._timer.daemon = True
        self._timer.start()

    def disarm(self):
        self.cancel()

    def cancel(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


class HeartbeatMonitor:
    """Tracks per-worker liveness; report() returns the surviving set."""

    def __init__(self, n_workers: int, timeout_s: float = 60.0):
        self.timeout = timeout_s
        self.last = {i: time.time() for i in range(n_workers)}

    def beat(self, worker: int):
        self.last[worker] = time.time()

    def survivors(self) -> list:
        now = time.time()
        return [w for w, t in self.last.items() if now - t < self.timeout]
