"""Pipeline parallelism: GPipe-style microbatch schedule over a mesh axis.

``split_stages`` carves a layer-stacked parameter pytree into S
contiguous stages; ``pipelined_apply`` runs the classic tick schedule
under shard_map: every tick each device applies its own stage to the
activation it holds, then a ``ppermute`` shifts activations one stage
forward while stage 0 feeds the next microbatch.  With M microbatches and
S stages the schedule drains in ``M + S - 1`` ticks (the pipeline
bubble), implemented as a single ``lax.scan`` over ticks so the HLO is
O(1) in both M and S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import compat


def split_stages(params, n_stages: int):
    """Split a layer-stacked pytree (leaves ``[L, ...]``) into
    ``n_stages`` equal contiguous stages (leaves ``[S, L/S, ...]``)."""
    def split(l):
        L = l.shape[0]
        if L % n_stages:
            raise ValueError(
                f"cannot split {L} stacked layers into {n_stages} stages")
        return l.reshape(n_stages, L // n_stages, *l.shape[1:])
    return jax.tree.map(split, params)


def pipelined_apply(stage_fn, stages, xs, mesh, axis: str = "pipe"):
    """Run ``xs`` ([n_micro, ...microbatch]) through the staged network.

    ``stage_fn(stage_params, x)`` applies ONE stage (its leaves are the
    ``[L/S, ...]`` slice of the layer stack) to one microbatch.  The
    stage dim of ``stages`` is sharded over ``mesh[axis]``; activations
    hop stage-to-stage via ppermute.  Returns ``[n_micro, ...]`` outputs,
    replicated.  Falls back to a sequential loop when ``mesh`` is None or
    lacks ``axis`` (so the same driver code runs unmeshed).
    """
    n_stages = int(jax.tree.leaves(stages)[0].shape[0])
    if mesh is None or axis not in mesh.axis_names \
            or int(mesh.shape[axis]) == 1:
        def seq(x):
            for s in range(n_stages):
                x = stage_fn(jax.tree.map(lambda l: l[s], stages), x)
            return x
        return jax.vmap(seq)(xs)

    if int(mesh.shape[axis]) != n_stages:
        raise ValueError(
            f"{n_stages} stages need mesh axis '{axis}' of that size, "
            f"got {int(mesh.shape[axis])}")
    n_micro = xs.shape[0]
    n_ticks = n_micro + n_stages - 1          # the pipeline bubble
    fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(stages_l, xs_l):
        p = jax.tree.map(lambda l: l[0], stages_l)   # this device's stage
        sid = jax.lax.axis_index(axis)
        buf0 = jnp.zeros(xs_l.shape[1:], xs_l.dtype)
        outs0 = jnp.zeros_like(xs_l)

        def tick(carry, t):
            buf, outs = carry
            feed = xs_l[jnp.minimum(t, n_micro - 1)]
            out = stage_fn(p, jnp.where(sid == 0, feed, buf))
            done = t - (n_stages - 1)         # microbatch finishing now
            keep = (sid == n_stages - 1) & (done >= 0)
            outs = jnp.where(
                keep, outs.at[jnp.clip(done, 0, n_micro - 1)].set(out),
                outs)
            # shift activations one stage forward (stage 0 gets zeros,
            # which it never reads — it always consumes the feed)
            return (jax.lax.ppermute(out, axis, fwd), outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_ticks))
        # only the last stage holds real outputs; psum replicates them
        return jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)

    stage_specs = jax.tree.map(
        lambda l: P(axis, *((None,) * (l.ndim - 1))), stages)
    rep = P(*((None,) * xs.ndim))
    fn = compat.shard_map(per_stage, mesh=mesh,
                          in_specs=(stage_specs, rep), out_specs=rep)
    return fn(stages, xs)
