"""Fused Pallas cycle megakernel for the CCA engine (DESIGN §6)."""
from repro.kernels.cca_cycle.ops import cca_cycle_chunk
from repro.kernels.cca_cycle.ref import cca_cycle_chunk_ref

__all__ = ["cca_cycle_chunk", "cca_cycle_chunk_ref"]
