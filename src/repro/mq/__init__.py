"""repro.mq — multi-tenant query serving over one evolving graph.

Q-batched diffusion (DESIGN §10): the vertex value slot carries one value
per concurrent query, app-like messages widen to vector payloads, and one
relaxation wave over the live structure serves every tenant at once.

  batch_app   build the composite :class:`DiffusionApp` over Q slot apps
  MQSession   the serving engine: admit / run / read back / retire queries
  FrontDesk   admission control + per-query latency accounting
"""
from repro.mq.app import batch_app
from repro.mq.frontdesk import FrontDesk, QueryRequest
from repro.mq.session import MQSession, QuerySlot

__all__ = ["batch_app", "MQSession", "QuerySlot", "FrontDesk",
           "QueryRequest"]
