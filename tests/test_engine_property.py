"""Property-based tests on the engine's system invariants (hypothesis):
for ARBITRARY random graphs, stream orders, chunkings and capacities the
streaming dynamic BFS must equal offline BFS, conserve every edge, and
respect allocator locality.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see pyproject)")
from hypothesis import given, settings, strategies as st

from repro.core import EngineConfig, StreamingEngine
from repro.core.reference import bfs_levels

ONE = np.float32(1.0).view(np.int32)


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(8, 48),
    m=st.integers(1, 150),
    n_inc=st.integers(1, 4),
    edge_cap=st.integers(2, 4),
    seed=st.integers(0, 2**31),
)
def test_streaming_bfs_always_matches_offline(n, m, n_inc, edge_cap, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    edges = np.stack([src[keep], dst[keep],
                      np.full(keep.sum(), ONE)], 1).astype(np.int32)
    cfg = EngineConfig(height=4, width=4, n_vertices=n, edge_cap=edge_cap,
                       ghost_slots=64, queue_cap=32, chan_cap=8,
                       futq_cap=8, io_stream_cap=4096, chunk=64)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    for part in np.array_split(edges, n_inc):
        if len(part):
            eng.run_increment(part, max_cycles=300_000)
    # 1) correctness vs offline BFS on the full edge set
    want = bfs_levels(n, edges, 0) if len(edges) else \
        np.where(np.arange(n) == 0, 0, 1e9).astype(np.float32)
    np.testing.assert_array_equal(eng.values(n), want)
    # 2) edge conservation across all RPVO chains
    assert int(np.asarray(eng.state.nedges).sum()) == len(edges)
    # 3) vicinity locality bound holds for every ghost link
    stats = eng.vertex_object_stats()
    assert stats["max_hops"] <= 2 * cfg.vicinity_hops
    # 4) monotonicity: levels are never below the offline answer
    assert (eng.values(n) >= want - 1e-6).all()
