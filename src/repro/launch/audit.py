import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""HLO collective audit: the §Perf loop's profiler substitute.

Prints per-collective byte totals and the top ops with op_name metadata
(which jaxpr op emitted them) — this is how the perf iterations localize
collective/memory waste without real-TPU traces.

  PYTHONPATH=src python -m repro.launch.audit --arch arctic-480b \
      --shape train_4k [--layers 1] [--mesh single]
"""
import argparse
import collections
import re


def audit(arch, shape_name, mesh_kind="single", layers=None, top=20):
    import jax
    from repro.configs.registry import get_shape
    from repro.dist.compat import use_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_plan

    bundle, spec = get_shape(arch, shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ov = None
    if layers is not None and bundle.family == "lm":
        ov = dict(n_layers=layers, attn_chunk=spec.dim("seq_len"))
    plan = build_plan(bundle, spec, mesh, lm_overrides=ov)
    with use_mesh(mesh):
        comp = jax.jit(plan.step, in_shardings=plan.in_shardings,
                       donate_argnums=plan.donate).lower(*plan.args).compile()
    txt = comp.as_text()
    pat = re.compile(
        r"= (\w+)\[([\d,]*)\][^ ]* "
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)\(")
    dt = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
          "s8": 1, "u8": 1, "f64": 8, "s64": 8}
    tot = collections.Counter()
    rows = []
    for line in txt.splitlines():
        m = pat.search(line)
        if not m:
            continue
        d, dims, kind = m.groups()
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        b = n * dt.get(d, 4)
        tot[kind] += b
        op = re.search(r'op_name="([^"]*)"', line)
        rows.append((b, kind, f"{d}[{dims}]",
                     (op.group(1) if op else "?")[-90:]))
    print(f"=== {arch}/{shape_name}/{mesh_kind} per-device collective "
          f"bytes ===")
    for k, v in sorted(tot.items()):
        print(f"  {k:20s} {v/1e9:8.2f} GB")
    rows.sort(reverse=True)
    print(f"=== top {top} ===")
    for b, kind, shp, op in rows[:top]:
        print(f"  {b/1e6:9.1f}MB {kind:18s} {shp:28s} {op}")
    return tot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args()
    audit(args.arch, args.shape, args.mesh, args.layers)


if __name__ == "__main__":
    main()
