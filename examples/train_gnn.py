"""Train a 2-layer GCN (the gcn-cora architecture) on a synthetic
Cora-like graph with the bulk message-passing substrate, plus one step of
GatedGCN to show the arch switch.

  PYTHONPATH=src python examples/train_gnn.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import shape
from repro.configs.registry import ARCHS
from repro.data.graphs import build_graph
from repro.models.gnn import gnn_forward, gnn_loss, init_gnn_params
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw

cfg = dataclasses.replace(ARCHS["gcn-cora"].config, d_in=64, d_out=7)
spec = shape("demo", "gnn_full", n_nodes=512, n_edges=4096, d_feat=64)
g = build_graph(cfg, spec)
rng = np.random.default_rng(0)
labels = jnp.asarray(rng.integers(0, 7, 512).astype(np.int32))
mask = jnp.ones((512,), jnp.float32)
batch = dict(graph=g, labels=labels, mask=mask)

params = init_gnn_params(cfg, jax.random.PRNGKey(0))
opt_cfg = AdamWConfig(lr=1e-2, total_steps=60)
opt = init_adamw(params)


@jax.jit
def step(params, opt, batch):
    loss, grads = jax.value_and_grad(
        lambda p: gnn_loss(cfg, p, batch))(params)
    params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
    return params, opt, loss


losses = []
for s in range(60):
    params, opt, loss = step(params, opt, batch)
    losses.append(float(loss))
    if s % 15 == 0:
        print(f"gcn step {s}: loss {float(loss):.4f}")
print(f"GCN loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert losses[-1] < losses[0]

# arch switch: one GatedGCN step on the same graph
cfg2 = dataclasses.replace(ARCHS["gatedgcn"].smoke(), d_in=64, d_out=7)
p2 = init_gnn_params(cfg2, jax.random.PRNGKey(1))
out = jax.jit(lambda p, g: gnn_forward(cfg2, p, g))(p2, g)
print("gatedgcn forward ok:", out.shape)
