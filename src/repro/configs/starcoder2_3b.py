"""--arch starcoder2-3b (exact published config; see lm_archs.py)."""
from repro.configs.lm_archs import STARCODER2_3B as CONFIG
from repro.configs.registry import get

BUNDLE = get("starcoder2-3b")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
