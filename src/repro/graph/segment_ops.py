"""Bulk message-passing primitives over edge lists.

JAX sparse is BCOO-only, so (per the assignment) message passing is built
on ``jax.ops.segment_sum`` / ``segment_max`` over an edge-index -> node
scatter.  This is also exactly the *bulk-synchronous* rendering of the
paper's diffusion: every edge carries an action (message) to its dst.

On TPU the gather/scatter hot path can be swapped for the one-hot MXU
SpMM Pallas kernel (repro.kernels.spmm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_src(x, edge_index):
    """x: [N, D]; edge_index: [2, E] (src, dst) -> messages [E, D]."""
    return x[edge_index[0]]


def scatter_sum(msgs, edge_index, n_nodes):
    return jax.ops.segment_sum(msgs, edge_index[1], num_segments=n_nodes)


def scatter_mean(msgs, edge_index, n_nodes):
    s = scatter_sum(msgs, edge_index, n_nodes)
    cnt = jax.ops.segment_sum(jnp.ones((msgs.shape[0],), msgs.dtype),
                              edge_index[1], num_segments=n_nodes)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(msgs, edge_index, n_nodes):
    return jax.ops.segment_max(msgs, edge_index[1], num_segments=n_nodes,
                               indices_are_sorted=False)


def degrees(edge_index, n_nodes, direction="in"):
    idx = edge_index[1] if direction == "in" else edge_index[0]
    return jax.ops.segment_sum(jnp.ones(idx.shape, jnp.float32), idx,
                               num_segments=n_nodes)


def sym_norm_coeff(edge_index, n_nodes, eps=1e-9):
    """GCN symmetric normalization 1/sqrt(d_src * d_dst) per edge."""
    din = degrees(edge_index, n_nodes, "in") + 1.0   # +1: self loops
    dout = degrees(edge_index, n_nodes, "out") + 1.0
    return jax.lax.rsqrt(dout[edge_index[0]] * din[edge_index[1]] + eps)


def spmm(x, edge_index, n_nodes, coeff=None, aggregator="sum"):
    """A @ X via gather-scatter.  coeff: optional per-edge scalar."""
    msgs = gather_src(x, edge_index)
    if coeff is not None:
        msgs = msgs * coeff[:, None]
    if aggregator == "sum":
        return scatter_sum(msgs, edge_index, n_nodes)
    if aggregator == "mean":
        return scatter_mean(msgs, edge_index, n_nodes)
    if aggregator == "max":
        return scatter_max(msgs, edge_index, n_nodes)
    raise ValueError(aggregator)


def segment_softmax(scores, seg_ids, n_segments):
    """Numerically stable softmax over variable-size segments (edge->dst)."""
    smax = jax.ops.segment_max(scores, seg_ids, num_segments=n_segments)
    ex = jnp.exp(scores - smax[seg_ids])
    ssum = jax.ops.segment_sum(ex, seg_ids, num_segments=n_segments)
    return ex / jnp.maximum(ssum[seg_ids], 1e-16)
