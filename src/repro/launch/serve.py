"""Batched serving driver: continuous-batching-lite decode loop.

Fixed batch slots; each slot holds one request with its own cache length.
Finished requests are replaced from the queue without stopping the batch
(the decode step is length-masked, so ragged slots are free).

  PYTHONPATH=src python -m repro.launch.serve --requests 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import PRESETS
from repro.models.transformer import (init_kv_cache, init_lm_params,
                                      lm_decode_step)
from repro.obs.metrics import render_summary, summarize


def serve(cfg, n_requests: int, batch: int, prompt_len: int = 16,
          gen_len: int = 24, max_len: int = 128, seed: int = 0):
    params = init_lm_params(cfg, jax.random.PRNGKey(seed))
    cache = init_kv_cache(cfg, batch, max_len)
    lengths = jnp.zeros((batch,), jnp.int32)
    rng = np.random.default_rng(seed)
    queue = [rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
             for _ in range(n_requests)]
    slots = [None] * batch          # request id per slot
    remaining = [0] * batch
    done, submitted = 0, 0
    step = jax.jit(lambda p, t, c, l: lm_decode_step(cfg, p, t, c, l))
    tokens_out = {i: [] for i in range(n_requests)}
    cur = jnp.zeros((batch, 1), jnp.int32)
    t0 = time.time()
    n_steps = 0
    step_times = []          # per-decode-step wall latency (repro.obs)
    while done < n_requests:
        # fill free slots (prefill = feeding prompt tokens one step at a
        # time here; the production prefill path is launch/steps.py's)
        for b in range(batch):
            if slots[b] is None and submitted < n_requests:
                slots[b] = submitted
                remaining[b] = prompt_len + gen_len
                lengths = lengths.at[b].set(0)
                submitted += 1
        # choose the next input token per slot
        nxt = []
        for b in range(batch):
            rid = slots[b]
            if rid is None:
                nxt.append(0)
                continue
            pos = int(lengths[b])
            if pos < prompt_len:
                nxt.append(int(queue[rid][pos]))
            else:
                nxt.append(int(cur[b, 0]))
        cur = jnp.asarray(nxt, jnp.int32)[:, None]
        ts = time.time()
        logits, cache = step(params, cur, cache, lengths)
        logits.block_until_ready()
        step_times.append(time.time() - ts)
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        lengths = lengths + jnp.asarray(
            [1 if slots[b] is not None else 0 for b in range(batch)],
            jnp.int32)
        n_steps += 1
        for b in range(batch):
            if slots[b] is None:
                continue
            rid = slots[b]
            if int(lengths[b]) > prompt_len:
                tokens_out[rid].append(int(cur[b, 0]))
            remaining[b] -= 1
            if remaining[b] <= 0:
                slots[b] = None
                done += 1
    dt = time.time() - t0
    tput = n_steps * batch / dt
    print(f"[serve] {n_requests} requests, {n_steps} steps, "
          f"{tput:.1f} tok/s aggregate")
    # metrics summary surface (repro.obs.metrics): decode-step latency
    # percentiles — step 0 is the jit compile, so report it separately
    print(render_summary("serve/decode_step", step_times[1:]))
    metrics = summarize([x * 1e3 for x in step_times[1:]], "ms")
    metrics.update(compile_ms=round(step_times[0] * 1e3, 1),
                   tok_per_s=round(tput, 1), steps=n_steps)
    return tokens_out, tput, metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="lm_tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    serve(PRESETS[args.preset], args.requests, args.batch,
          gen_len=args.gen_len)


if __name__ == "__main__":
    main()
