"""Reference oracles — the paper verifies against NetworkX (§4)."""
from __future__ import annotations

import numpy as np


def bfs_levels(n: int, edges: np.ndarray, source: int = 0,
               symmetric: bool = False) -> np.ndarray:
    """NetworkX single_source_shortest_path_length, dense output (INF=1e9)."""
    import networkx as nx
    g = nx.DiGraph()
    g.add_nodes_from(range(n))
    g.add_edges_from((int(s), int(d)) for s, d, *_ in edges)
    if symmetric:
        g.add_edges_from((int(d), int(s)) for s, d, *_ in edges)
    out = np.full(n, 1e9, np.float32)
    for v, l in nx.single_source_shortest_path_length(g, source).items():
        out[v] = l
    return out


def sssp_dists(n: int, edges: np.ndarray, weights: np.ndarray,
               source: int = 0) -> np.ndarray:
    import networkx as nx
    g = nx.DiGraph()
    g.add_nodes_from(range(n))
    for (s, d), w in zip(edges[:, :2], weights):
        if g.has_edge(int(s), int(d)):
            w = min(w, g[int(s)][int(d)]["weight"])
        g.add_edge(int(s), int(d), weight=float(w))
    out = np.full(n, 1e9, np.float32)
    for v, l in nx.single_source_dijkstra_path_length(g, source).items():
        out[v] = l
    return out


def cc_labels(n: int, edges: np.ndarray) -> np.ndarray:
    """Min-vertex-id label per weakly connected component."""
    import networkx as nx
    g = nx.Graph()
    g.add_nodes_from(range(n))
    g.add_edges_from((int(s), int(d)) for s, d, *_ in edges)
    out = np.zeros(n, np.float32)
    for comp in nx.connected_components(g):
        m = min(comp)
        for v in comp:
            out[v] = m
    return out
