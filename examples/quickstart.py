"""Quickstart: stream edges into the message-driven engine and watch
dynamic BFS update incrementally — the paper's core demo in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import EngineConfig, StreamingEngine
from repro.core.reference import bfs_levels

# an 8x8 chip of compute cells hosting 64 vertices
cfg = EngineConfig(height=8, width=8, n_vertices=64, edge_cap=4,
                   ghost_slots=16)
engine = StreamingEngine(cfg, app="bfs")
engine.seed(0, 0.0)                      # BFS source: vertex 0 at level 0

rng = np.random.default_rng(0)
one = np.float32(1.0).view(np.int32)

for increment in range(3):
    src = rng.integers(0, 64, 40)
    dst = rng.integers(0, 64, 40)
    edges = np.stack([src, dst, np.full(40, one)], 1).astype(np.int32)
    edges = edges[src != dst]
    result = engine.run_increment(edges)
    print(f"increment {increment}: {len(edges)} edges streamed in "
          f"{result.cycles} cycles, {result.execs} actions executed, "
          f"{result.allocs} ghost vertices allocated")

levels = engine.values(64)
print("BFS levels of first 16 vertices:", levels[:16])
print("reachable:", int((levels < 1e9).sum()), "/ 64")
