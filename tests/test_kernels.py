"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracle,
executed in Pallas interpret mode on CPU (TPU is the deploy target).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: only the property-based tests skip
    def given(**kw):
        return lambda f: pytest.mark.skip(
            reason="property tests need hypothesis (see pyproject)")(f)

    def settings(**kw):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.kernels.embedding_bag.ops import (embedding_bag,
                                             embedding_bag_reference)
from repro.kernels.flash_attention.ops import (attention_reference,
                                               flash_attention)
from repro.kernels.spmm.ops import spmm_reference, spmm_sorted_coo


# ----------------------------- flash attention -----------------------------

@pytest.mark.parametrize("B,T,H,Kh,dh", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 256, 4, 1, 128),    # MQA
    (2, 128, 8, 4, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, T, H, Kh, dh, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, T, H, dh), dtype)
    k = jax.random.normal(k2, (B, T, Kh, dh), dtype)
    v = jax.random.normal(k3, (B, T, Kh, dh), dtype)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                          interpret=True)
    want = attention_reference(q, k, v, causal=True)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


def test_flash_attention_block_shapes():
    """Block size must not change the result."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (1, 256, 2, 64))
    k = jax.random.normal(k2, (1, 256, 2, 64))
    v = jax.random.normal(k3, (1, 256, 2, 64))
    a = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    b = flash_attention(q, k, v, bq=128, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_flash_vs_xla_path():
    """The model's chunked-XLA attention agrees with kernel + oracle."""
    from repro.models.transformer import flash_attention_xla
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (2, 128, 4, 32))
    k = jax.random.normal(k2, (2, 128, 2, 32))
    v = jax.random.normal(k3, (2, 128, 2, 32))
    a = flash_attention_xla(q, k, v, causal=True, chunk=32)
    want = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


# ----------------------------------- spmm -----------------------------------

@pytest.mark.parametrize("N,E,D", [(64, 512, 32), (200, 1000, 64),
                                   (128, 128, 128), (8, 4000, 16)])
def test_spmm_sweep(N, E, D):
    rng = np.random.default_rng(0)
    src = rng.integers(0, N, E).astype(np.int32)
    dst = np.sort(rng.integers(0, N, E).astype(np.int32))
    x = rng.standard_normal((N, D), dtype=np.float32)
    got = spmm_sorted_coo(x, src, dst, N, bn=32, be=64, interpret=True)
    want = spmm_reference(x[src], dst, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_spmm_weighted():
    rng = np.random.default_rng(1)
    N, E, D = 50, 300, 24
    src = rng.integers(0, N, E).astype(np.int32)
    dst = np.sort(rng.integers(0, N, E).astype(np.int32))
    x = rng.standard_normal((N, D), dtype=np.float32)
    w = rng.standard_normal(E).astype(np.float32)
    got = spmm_sorted_coo(x, src, dst, N, coeff=w, bn=16, be=32,
                          interpret=True)
    want = spmm_reference(x[src] * w[:, None], dst, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 60), e=st.integers(1, 200), d=st.integers(1, 40),
       seed=st.integers(0, 2**31))
def test_spmm_property(n, e, d, seed):
    """Property: kernel == segment_sum for arbitrary sorted COO inputs."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = np.sort(rng.integers(0, n, e).astype(np.int32))
    x = rng.standard_normal((n, d), dtype=np.float32)
    got = spmm_sorted_coo(x, src, dst, n, bn=16, be=32, interpret=True)
    want = spmm_reference(x[src], dst, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


# ------------------------------ embedding bag ------------------------------

@pytest.mark.parametrize("V,D,B,L", [(128, 64, 16, 4), (1000, 32, 8, 1),
                                     (64, 128, 32, 8)])
@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_embedding_bag_sweep(V, D, B, L, combiner):
    rng = np.random.default_rng(0)
    table = rng.standard_normal((V, D), dtype=np.float32)
    idx = rng.integers(0, V, (B, L)).astype(np.int32)
    got = embedding_bag(table, idx, combiner=combiner, interpret=True)
    want = embedding_bag_reference(table, idx, combiner=combiner)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(v=st.integers(2, 300), d=st.integers(1, 64), b=st.integers(1, 16),
       l=st.integers(1, 8), seed=st.integers(0, 2**31))
def test_embedding_bag_property(v, d, b, l, seed):
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((v, d), dtype=np.float32)
    idx = rng.integers(0, v, (b, l)).astype(np.int32)
    got = embedding_bag(table, idx, interpret=True)
    want = embedding_bag_reference(table, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
