"""repro.dist — the distribution layer (DESIGN §5).

One CCA state / model pytree, sharded across a device mesh by GSPMD,
behind a single programming abstraction:

* :mod:`repro.dist.ctx`      — process-global mesh registry + ``constrain``
* :mod:`repro.dist.sharding` — per-family sharding rules (CCA state,
  LM, GNN, DLRM) + ``pad_to``
* :mod:`repro.dist.pipeline` — microbatch pipeline parallelism
* :mod:`repro.dist.compat`   — jax version shims (installed on import)
"""
from repro.dist import compat  # noqa: F401  (installs the jax API shims)
