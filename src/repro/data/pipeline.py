"""Deterministic, resumable synthetic data pipelines.

Every pipeline is a pure function of (seed, step) so a restarted job
regenerates the identical batch stream from the checkpointed step — the
data-side half of fault-tolerant training (train/checkpoint.py stores the
step; nothing else is needed to resume bit-identically).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMBatchSpec:
    batch: int
    seq_len: int
    vocab: int
    seed: int = 0


def lm_batch(spec: LMBatchSpec, step: int) -> dict:
    rng = np.random.default_rng((spec.seed << 20) ^ step)
    # zipf-ish token distribution (more realistic activation stats)
    z = rng.zipf(1.3, size=(spec.batch, spec.seq_len + 1))
    toks = (z % spec.vocab).astype(np.int32)
    return dict(tokens=toks[:, :-1], targets=toks[:, 1:])


@dataclasses.dataclass(frozen=True)
class RecSysBatchSpec:
    batch: int
    n_dense: int
    n_sparse: int
    lookups: int
    vocab_sizes: tuple
    seed: int = 0


def recsys_batch(spec: RecSysBatchSpec, step: int) -> dict:
    rng = np.random.default_rng((spec.seed << 20) ^ step)
    dense = rng.standard_normal((spec.batch, spec.n_dense),
                                dtype=np.float32)
    sparse = np.stack(
        [rng.integers(0, v, size=(spec.batch, spec.lookups))
         for v in spec.vocab_sizes], axis=1).astype(np.int32)
    labels = rng.integers(0, 2, size=(spec.batch,)).astype(np.int32)
    return dict(dense=dense, sparse=sparse, labels=labels)
