"""Machine state: the whole AM-CCA chip as one fixed-shape pytree.

Slot layout per cell: slots ``[0, P)`` with ``P = rhizome_cap * root_slots``
are the statically partitioned rhizome-root region — slot
``k * root_slots + j`` is rhizome root ``k`` of the vertex with local index
``j`` (root 0 at cell ``v % n_cells`` is the classic canonical RPVO root).
Slots ``[P, S)`` are ghost slots handed out by the allocator.  A global
address is ``addr = cell * S + slot`` (int32).

Secondary rhizome roots (k >= 1) start *inactive* (``rhz_on`` False) and are
grown on demand by the OP_LINK_RHIZOME protocol (DESIGN §4.5): an insert
arriving at an inactive root is deferred on the slot's future queue exactly
like the ghost G_PENDING protocol, and drains when the canonical root's
value-carrying OP_RHIZOME_FWD ack activates the slot.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import EngineConfig
from repro.core.msg import N_DIRS

# ghost-future states (paper Fig. 4)
G_NULL, G_PENDING, G_SET = 0, 1, 2

INF = jnp.float32(1e9)

# ---- telemetry plane indices (repro.obs, DESIGN §8) ----
# Per-cell per-stage activity counts, ``tm_cell [H, W, N_TM_STAGES]``.
# The counts are CUMULATIVE over an increment (reset with the stat_*
# scalars) so the final plane reconciles exactly with the scalar
# counters: sum(TM_HOP) == stat_hops, sum(TM_EXEC) == stat_exec at
# quiescence, sum(TM_STALL) + sum(TM_PARK) == stat_stall,
# sum(TM_ALLOC) == stat_allocs.
TM_EXEC = 0     # actions popped by phase0 (== completed at quiescence)
TM_ALLOC = 1    # ghost allocations served here
TM_STALL = 2    # staging backpressure stalls + phase0 head rotations
TM_HOP = 3      # flits accepted into this cell by the hop stage
TM_STAGE = 4    # emissions staged successfully (network or local queue)
TM_PARK = 5     # remote emissions parked (lane full at staging time)
TM_UNPARK = 6   # parked messages re-injected into a lane
TM_IO = 7       # streamed edge inserts accepted at this IO cell
TM_BCAST = 8    # rhizome sibling broadcasts staged (fan-out traffic)
N_TM_STAGES = 9

# Per-link per-lane counters, ``tm_lane [H, W, 4, L, N_TM_LANE]``.
TM_L_OCC = 0    # sum of lane occupancy per cycle (avg depth = OCC/cycles)
TM_L_GRANT = 1  # arbiter grants won AND accepted (== hops on this lane)
TM_L_BLOCK = 2  # cycles the lane was occupied but not granted
N_TM_LANE = 3

# Per-cell hi-water marks, ``tm_hiw [H, W, N_TM_HIW]``.
TM_HW_AQ = 0    # action-queue depth hi-water
TM_HW_PK = 1    # park-ring depth hi-water
N_TM_HIW = 2


class MachineState(NamedTuple):
    # --- RPVO slot storage [H, W, S, ...] ---
    vals: jax.Array        # [H,W,S,VN] f32  application values (BFS level, ...)
    nedges: jax.Array      # [H,W,S]    i32  edges in this RPVO node
    edst: jax.Array        # [H,W,S,E]  i32  edge dst = root addr of dst vertex
    ew: jax.Array          # [H,W,S,E]  f32  edge weight
    gaddr: jax.Array       # [H,W,S]    i32  ghost address (-1 if none)
    gstate: jax.Array      # [H,W,S]    i32  future state: null/pending/set
    rhz_on: jax.Array      # [H,W,S]    bool secondary rhizome root is active
    rstate: jax.Array      # [H,W,S]    i32  rhizome-link state (G_* codes)
    nfree: jax.Array       # [H,W]      i32  next free ghost slot
    # --- future LCO deferred queues [H,W,S,FQ,3]: (op, arg0, arg1) ---
    fq: jax.Array
    fq_n: jax.Array        # [H,W,S] i32
    fq_head: jax.Array     # [H,W,S] i32
    # --- coalesced deferred app-forward (futures merge monotone relaxes) ---
    fwd_val: jax.Array     # [H,W,S] f32
    fwd_pending: jax.Array # [H,W,S] bool
    # --- per-cell action queue ---
    aq: jax.Array          # [H,W,Q,MSG] i32
    aq_n: jax.Array        # [H,W] i32
    aq_head: jax.Array     # [H,W] i32
    # --- per-cell, per-direction outgoing channels, lane-major (§7):
    #     each physical link carries cfg.lanes independently-queued
    #     virtual lanes of cfg.lane_capacity messages each ---
    ch: jax.Array          # [H,W,4,L,LC,MSG] i32
    ch_n: jax.Array        # [H,W,4,L] i32
    ch_head: jax.Array     # [H,W,4,L] i32
    ch_rr: jax.Array       # [H,W,4] i32  round-robin lane-arbiter pointer
    # --- per-cell park buffer (§7): stalled remote emissions store here
    #     (separate from the action queue so in-transit messages never
    #     hold it above the admission thresholds); lanes=1 -> 1-deep dummy
    pk: jax.Array          # [H,W,PK,MSG] i32
    pk_n: jax.Array        # [H,W] i32
    pk_head: jax.Array     # [H,W] i32
    # --- active-action registers (serialized execute/propagate; 1 op/cycle) ---
    cmsg: jax.Array        # [H,W,MSG] i32
    cvalid: jax.Array      # [H,W] bool
    cphase: jax.Array      # [H,W] i32   emissions staged so far + 1
    cT: jax.Array          # [H,W] i32   total emissions of the active action
    cemit: jax.Array       # [H,W] f32   snapshot of the emission source value
    cout: jax.Array        # [H,W,MSG] i32 precomputed single emission
    cdrain: jax.Array      # [H,W] i32   deferred-queue drains of active action
    # --- IO cells (streaming ingestion) ---
    io_edges: jax.Array    # [IO, L, 3] i32 (src vid, dst vid, weight bits)
    io_n: jax.Array        # [IO] i32 edges loaded
    io_pos: jax.Array      # [IO] i32 cursor
    # --- allocator rotation counters ---
    arot: jax.Array        # [H,W] i32
    # --- cycle counters / stats (per-chunk, host-accumulated) ---
    cycle: jax.Array       # scalar i32
    stat_hops: jax.Array   # scalar i32 (reset per chunk; host accumulates)
    stat_exec: jax.Array   # scalar i32 actions completed
    stat_stall: jax.Array  # scalar i32 staging stalls
    stat_allocs: jax.Array # scalar i32 ghost allocations
    # --- telemetry planes (repro.obs, DESIGN §8): accumulated inside the
    #     cycle stages when cfg.telemetry, snapshotted per chunk into the
    #     on-device frame ring; 1x1-shaped dummies (never touched) when
    #     telemetry is off so the off path stays bit-exact and free ---
    tm_cell: jax.Array     # [H,W,N_TM_STAGES] i32 per-cell stage activity
    tm_lane: jax.Array     # [H,W,4,L,N_TM_LANE] i32 lane occ/grant/blocked
    tm_hiw: jax.Array      # [H,W,N_TM_HIW] i32 AQ / park-ring hi-water
    # --- fault-injection counters (repro.resilience, DESIGN §9):
    #     [N_FLT] i32 (FLT_* indices in resilience/faults.py) when
    #     cfg.faults is set, else a [1] dummy — same pattern as the
    #     telemetry planes, so faults=None stays bit-exact and the
    #     Pallas megakernel carries the leaf through its generic
    #     flattening with zero kernel changes ---
    flt: jax.Array
    # --- per-query quiescence counters (repro.mq, DESIGN §10): when
    #     cfg.qbatch > 1, qchg[q] counts relax changes of query slot q
    #     (reset per increment with the stat_* scalars) and qlast[q]
    #     holds the machine cycle of slot q's last change — the per-slot
    #     changed-bits folded into the stat record that mq/session.py
    #     reads for per-query time-to-quiescence and slot retirement.
    #     [1] dummies (never touched) when qbatch == 1 ---
    qchg: jax.Array        # [Q] i32 (or [1] dummy)
    qlast: jax.Array       # [Q] i32 (or [1] dummy)


def init_state(cfg: EngineConfig,
               init_vals: float | np.ndarray = 1e9,
               fwd_init: float | np.ndarray = 1e9) -> MachineState:
    """Fresh machine: all vertices allocated as roots, no edges, empty queues.

    ``init_vals`` may be a ``[n_vals]`` vector (per-query init values when
    ``cfg.qbatch > 1``); ``fwd_init`` is the neutral element of the
    coalescing forward register (``app.fwd_neutral`` — 1e9 for the
    min-monotone apps), likewise scalar or per-query.
    """
    cfg.validate()
    H, W, S, E = cfg.height, cfg.width, cfg.slots, cfg.edge_cap
    VN, FQ, Q = cfg.n_vals, cfg.futq_cap, cfg.queue_cap
    VL, LC = cfg.lanes, cfg.lane_capacity
    IO, L = cfg.io_cells, cfg.io_stream_cap
    QB, WM = cfg.qbatch, cfg.msg_words
    z32 = lambda *s: jnp.zeros(s, jnp.int32)
    vals = jnp.full((H, W, S, VN), jnp.float32(init_vals))
    # qbatch > 1 widens the emission snapshot and the forward register
    # with the query axis (DESIGN §10); qbatch == 1 keeps the classic
    # scalar shapes so the pre-mq trace is unchanged
    fwd_shape = (H, W, S) if QB == 1 else (H, W, S, QB)
    cemit_shape = (H, W) if QB == 1 else (H, W, QB)
    return MachineState(
        vals=vals,
        nedges=z32(H, W, S),
        edst=jnp.full((H, W, S, E), -1, jnp.int32),
        ew=jnp.zeros((H, W, S, E), jnp.float32),
        gaddr=jnp.full((H, W, S), -1, jnp.int32),
        gstate=z32(H, W, S),
        rhz_on=jnp.zeros((H, W, S), bool),
        rstate=z32(H, W, S),
        nfree=jnp.full((H, W), cfg.primary_slots, jnp.int32),
        fq=z32(H, W, S, FQ, 3),
        fq_n=z32(H, W, S), fq_head=z32(H, W, S),
        fwd_val=jnp.full(fwd_shape, jnp.float32(fwd_init)),
        fwd_pending=jnp.zeros((H, W, S), bool),
        aq=z32(H, W, Q, WM), aq_n=z32(H, W), aq_head=z32(H, W),
        ch=z32(H, W, N_DIRS, VL, LC, WM),
        ch_n=z32(H, W, N_DIRS, VL), ch_head=z32(H, W, N_DIRS, VL),
        ch_rr=z32(H, W, N_DIRS),
        pk=z32(H, W, cfg.park_capacity, WM),
        pk_n=z32(H, W), pk_head=z32(H, W),
        cmsg=z32(H, W, WM),
        cvalid=jnp.zeros((H, W), bool),
        cphase=z32(H, W), cT=z32(H, W),
        cemit=jnp.zeros(cemit_shape, jnp.float32),
        cout=z32(H, W, WM),
        cdrain=z32(H, W),
        io_edges=z32(IO, L, 3), io_n=z32(IO), io_pos=z32(IO),
        arot=z32(H, W),
        cycle=jnp.int32(0), stat_hops=jnp.int32(0), stat_exec=jnp.int32(0),
        stat_stall=jnp.int32(0), stat_allocs=jnp.int32(0),
        tm_cell=z32(*((H, W) if cfg.telemetry else (1, 1)), N_TM_STAGES),
        tm_lane=z32(*((H, W, N_DIRS, VL) if cfg.telemetry
                      else (1, 1, 1, 1)), N_TM_LANE),
        tm_hiw=z32(*((H, W) if cfg.telemetry else (1, 1)), N_TM_HIW),
        flt=z32(4 if cfg.faults is not None else 1),
        qchg=z32(QB if QB > 1 else 1),
        qlast=z32(QB if QB > 1 else 1),
    )


# ---------------- addressing helpers ----------------

def root_addr(cfg: EngineConfig, vid):
    """Global address of vertex vid's RPVO root."""
    vid = jnp.asarray(vid, jnp.int32)
    cell = vid % cfg.n_cells
    slot = vid // cfg.n_cells
    return cell * cfg.slots + slot


def addr_cell(cfg: EngineConfig, addr):
    return addr // cfg.slots


def addr_slot(cfg: EngineConfig, addr):
    return addr % cfg.slots


def cell_rc(cfg: EngineConfig, cell):
    return cell // cfg.width, cell % cfg.width


def self_cell_grid(cfg: EngineConfig):
    """[H,W] array of flat cell ids."""
    return (jnp.arange(cfg.height, dtype=jnp.int32)[:, None] * cfg.width
            + jnp.arange(cfg.width, dtype=jnp.int32)[None, :])
