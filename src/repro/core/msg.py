"""Active-message ("action") codec.

A message is a fixed 5-word int32 record::

    word 0  opcode        (OP_*, 0 = empty)
    word 1  dst address   (cell * slots + slot)
    word 2  arg0
    word 3  arg1
    word 4  arg2

Float arguments (application values, e.g. BFS levels) are bit-cast into
int32 words -- the 256-bit AM-CCA flit carries opaque operand words the
same way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MSG_WORDS = 5

# ---- opcodes ----
OP_NOP = 0
OP_INSERT_EDGE = 1    # args: (edge dst root addr, weight bits, -)
OP_APP = 2            # args: (value bits, -, -)   the application action (e.g. bfs-action)
OP_ALLOC = 3          # args: (requester addr, requester value bits, -)
OP_SET_FUTURE = 4     # args: (new ghost addr, -, -)
OP_RHIZOME_FWD = 5    # args: (value bits, -, -)   sibling-rhizome value sync;
                      # also the link-ack that activates a pending rhizome root
OP_LINK_RHIZOME = 6   # args: (requester rhizome addr, -, -) sent to the
                      # canonical root to request activation of a sibling
OP_REPAIR = 7         # args: (value bits, -, -)   recovery-path relax
                      # (DESIGN §9): relaxes like OP_APP but *forces*
                      # re-diffusion over the slot's local edge shard and
                      # down the ghost chain even when the value did not
                      # change — injected by the engine's repair pass to
                      # rebuild state lost to dropped/corrupted app flits
N_OPS = 8

# ---- directions (mesh links) ----
DIR_N, DIR_S, DIR_W, DIR_E = 0, 1, 2, 3
N_DIRS = 4

# ---- staging target-buffer codes (exec stage) ----
TB_NONE = -1
TB_CHAN_N, TB_CHAN_S, TB_CHAN_W, TB_CHAN_E = 0, 1, 2, 3
TB_AQ_SELF = 4
TB_FUTQ = 5


def f2i(x):
    """Bit-cast float32 -> int32 (payload word)."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.int32)


def i2f(x):
    """Bit-cast int32 -> float32."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.int32), jnp.float32)


def make_msg(op, dst, a0=0, a1=0, a2=0):
    """Build a message; broadcasting over leading dims."""
    parts = jnp.broadcast_arrays(
        jnp.asarray(op, jnp.int32), jnp.asarray(dst, jnp.int32),
        jnp.asarray(a0, jnp.int32), jnp.asarray(a1, jnp.int32),
        jnp.asarray(a2, jnp.int32))
    return jnp.stack(parts, axis=-1)


def msg_op(m):
    return m[..., 0]


def msg_dst(m):
    return m[..., 1]


def msg_arg(m, i):
    return m[..., 2 + i]


def msg_seal(m):
    """Integrity seal of a message: XOR of words 0..3 (word 4 is the
    seal slot — unused as an operand by every opcode).  Set at the two
    network-injection chokepoints (staging emissions, IO inserts) when
    ``cfg.faults`` is active; validated by the execute stage at pop so a
    transit-corrupted flit is discarded as a counted no-op instead of
    poisoning the monotone fixpoint (DESIGN §9)."""
    return m[..., 0] ^ m[..., 1] ^ m[..., 2] ^ m[..., 3]


def seal_msg(m):
    """Return ``m`` with word 4 set to :func:`msg_seal`."""
    return jnp.concatenate(
        [m[..., :4], msg_seal(m)[..., None]], axis=-1)


EMPTY_MSG = (0, 0, 0, 0, 0)
