"""GNN model zoo: GCN, GatedGCN, MeshGraphNet, GraphCast.

All four assigned GNN architectures share the bulk message-passing
substrate (graph/segment_ops).  Each model is a (init, forward) pair over
a `Graph` batch:

    Graph(x [N,Dx], edge_index [2,E], e [E,De] | None, n_nodes, ...)

GraphCast is the encoder-processor-decoder variant: grid nodes are encoded
onto an icosahedral multimesh, `n_layers` MeshGraphNet-style blocks run on
the mesh, and the result is decoded back to the grid (arXiv:2212.12794).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.segment_ops import (gather_src, scatter_mean, scatter_sum,
                                     spmm, sym_norm_coeff)
from repro.models.common import dense_init, layer_norm, mlp_apply, mlp_init


def icosphere_sizes(refinement: int) -> tuple[int, int]:
    """(n_mesh_nodes, n_multimesh_directed_edges) for refinement r."""
    n = 10 * 4 ** refinement + 2
    e = sum(60 * 4 ** l for l in range(refinement + 1))
    return n, e


class Graph(NamedTuple):
    x: jax.Array                  # [N, Dx] node features
    edge_index: jax.Array         # [2, E]
    e: Any = None                 # [E, De] edge features (optional)
    # GraphCast only: the mesh graph + cross graphs
    mesh_edge_index: Any = None   # [2, Em] mesh<->mesh
    g2m_edge_index: Any = None    # [2, Eg2m] grid->mesh
    m2g_edge_index: Any = None    # [2, Em2g] mesh->grid


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "gnn"
    kind: str = "gcn"             # gcn | gatedgcn | meshgraphnet | graphcast
    n_layers: int = 2
    d_hidden: int = 16
    d_in: int = 1433
    d_out: int = 7
    d_edge_in: int = 0
    aggregator: str = "mean"
    mlp_layers: int = 2           # meshgraphnet MLP depth
    mesh_refinement: int = 6      # graphcast icosphere refinement
    n_vars: int = 227             # graphcast input variables
    dropout: float = 0.0
    compute_dtype: Any = jnp.float32

    def n_params(self) -> int:
        import jax.random as jr
        p = init_gnn_params(self, jr.PRNGKey(0))
        from repro.models.common import count_params
        return count_params(p)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_gnn_params(cfg: GNNConfig, key):
    k = iter(jax.random.split(key, 16 + 8 * cfg.n_layers))
    D = cfg.d_hidden
    if cfg.kind == "gcn":
        sizes = [cfg.d_in] + [D] * (cfg.n_layers - 1) + [cfg.d_out]
        return dict(w=[dense_init(next(k), (sizes[i], sizes[i + 1]))
                       for i in range(cfg.n_layers)],
                    b=[jnp.zeros((sizes[i + 1],)) for i in range(cfg.n_layers)])
    if cfg.kind == "gatedgcn":
        layers = []
        for _ in range(cfg.n_layers):
            layers.append(dict(
                A=dense_init(next(k), (D, D)), B=dense_init(next(k), (D, D)),
                C=dense_init(next(k), (D, D)), U=dense_init(next(k), (D, D)),
                V=dense_init(next(k), (D, D)),
                ln_h=jnp.ones((D,)), ln_hb=jnp.zeros((D,)),
                ln_e=jnp.ones((D,)), ln_eb=jnp.zeros((D,))))
        return dict(
            embed_h=dense_init(next(k), (cfg.d_in, D)),
            embed_e=dense_init(next(k), (max(cfg.d_edge_in, 1), D)),
            layers=layers,
            readout=dense_init(next(k), (D, cfg.d_out)))
    if cfg.kind == "meshgraphnet":
        def mgn_mlp(din):
            sizes = [din] + [D] * (cfg.mlp_layers - 1) + [D]
            return mlp_init(next(k), sizes)
        layers = [dict(edge=mgn_mlp(3 * D), node=mgn_mlp(2 * D),
                       ln_e=jnp.ones((D,)), ln_eb=jnp.zeros((D,)),
                       ln_h=jnp.ones((D,)), ln_hb=jnp.zeros((D,)))
                  for _ in range(cfg.n_layers)]
        return dict(
            enc_node=mlp_init(next(k), [cfg.d_in, D, D]),
            enc_edge=mlp_init(next(k), [max(cfg.d_edge_in, 1), D, D]),
            layers=layers,
            dec=mlp_init(next(k), [D, D, cfg.d_out]))
    if cfg.kind == "graphcast":
        def mlp2(din, dout=None):
            return mlp_init(next(k), [din, D, dout or D])
        layers = [dict(edge=mlp2(3 * D), node=mlp2(2 * D))
                  for _ in range(cfg.n_layers)]
        return dict(
            enc_grid=mlp2(cfg.d_in),
            enc_mesh=mlp2(3),                  # mesh static features (xyz)
            g2m_edge=mlp2(4), m2g_edge=mlp2(4), mesh_edge=mlp2(4),
            g2m=dict(edge=mlp2(3 * D), node=mlp2(2 * D)),
            layers=layers,
            m2g=dict(edge=mlp2(3 * D), node=mlp2(2 * D)),
            dec=mlp2(D, cfg.d_out))
    raise ValueError(cfg.kind)


# --------------------------------------------------------------------------
# forwards
# --------------------------------------------------------------------------

def _interaction_block(lp, h_src, h_dst, e, edge_index, n_dst):
    """MeshGraphNet block: edge MLP + node MLP with residuals."""
    m = jnp.concatenate([e, h_src[edge_index[0]], h_dst[edge_index[1]]], -1)
    e2 = e + mlp_apply(lp["edge"], m, act=jax.nn.relu)
    agg = scatter_sum(e2, edge_index, n_dst)
    h2 = h_dst + mlp_apply(lp["node"], jnp.concatenate([h_dst, agg], -1),
                           act=jax.nn.relu)
    return h2, e2


def gnn_forward(cfg: GNNConfig, params, g: Graph):
    cd = cfg.compute_dtype
    n = g.x.shape[0]
    if cfg.kind == "gcn":
        from repro.dist.ctx import get_dist_mesh
        mesh = get_dist_mesh()
        coeff = sym_norm_coeff(g.edge_index, n)
        h = g.x.astype(cd)
        for i in range(cfg.n_layers):
            h = h @ params["w"][i] + params["b"][i]
            if mesh is not None:
                # owner-partitioned edges: one bf16 all-gather per layer,
                # local scatter (no all-reduce) — §Perf gcn-cora iteration
                from repro.graph.partition import spmm_partitioned
                agg = spmm_partitioned(h, g.edge_index, n, coeff, mesh)
            else:
                agg = spmm(h, g.edge_index, n, coeff, "sum")
            h = agg.astype(cd) + h  # + self loop
            if i < cfg.n_layers - 1:
                h = jax.nn.relu(h)
        return h
    if cfg.kind == "gatedgcn":
        h = g.x.astype(cd) @ params["embed_h"]
        e_in = g.e if g.e is not None else \
            jnp.ones((g.edge_index.shape[1], 1), cd)
        e = e_in.astype(cd) @ params["embed_e"]
        for lp in params["layers"]:
            hs, hd = h[g.edge_index[0]], h[g.edge_index[1]]
            e_new = hs @ lp["A"] + hd @ lp["B"] + e @ lp["C"]
            eta = jax.nn.sigmoid(e_new)
            num = scatter_sum(eta * (hs @ lp["V"]), g.edge_index, n)
            den = scatter_sum(eta, g.edge_index, n)
            h_new = h @ lp["U"] + num / (den + 1e-6)
            h = h + jax.nn.relu(layer_norm(h_new, lp["ln_h"], lp["ln_hb"]))
            e = e + jax.nn.relu(layer_norm(e_new, lp["ln_e"], lp["ln_eb"]))
        return h @ params["readout"]
    if cfg.kind == "meshgraphnet":
        h = mlp_apply(params["enc_node"], g.x.astype(cd))
        e_in = g.e if g.e is not None else \
            jnp.ones((g.edge_index.shape[1], 1), cd)
        e = mlp_apply(params["enc_edge"], e_in.astype(cd))
        for lp in params["layers"]:
            h2, e2 = _interaction_block(lp, h, h, e, g.edge_index, n)
            h = layer_norm(h2, lp["ln_h"], lp["ln_hb"])
            e = layer_norm(e2, lp["ln_e"], lp["ln_eb"])
        return mlp_apply(params["dec"], h)
    if cfg.kind == "graphcast":
        return _graphcast_forward(cfg, params, g)
    raise ValueError(cfg.kind)


def _graphcast_forward(cfg: GNNConfig, params, g: Graph):
    """Encoder (grid->mesh) / processor (mesh) / decoder (mesh->grid)."""
    cd = cfg.compute_dtype
    n_grid = g.x.shape[0]
    n_mesh = icosphere_sizes(cfg.mesh_refinement)[0]  # static
    h_grid = mlp_apply(params["enc_grid"], g.x.astype(cd))
    # static mesh features: use 3 pseudo-coordinates derived from index
    mi = jnp.arange(n_mesh, dtype=cd)[:, None]
    mesh_feat = jnp.concatenate([jnp.sin(mi * 0.01), jnp.cos(mi * 0.01),
                                 mi / max(n_mesh, 1)], axis=-1)
    h_mesh = mlp_apply(params["enc_mesh"], mesh_feat)

    def edge_feat(ei, n_a, n_b):
        d = (ei[0].astype(cd) / max(n_a, 1) -
             ei[1].astype(cd) / max(n_b, 1))[:, None]
        return jnp.concatenate([d, jnp.abs(d), jnp.sin(d), jnp.cos(d)], -1)

    # grid -> mesh encoder block (bipartite interaction)
    e_g2m = mlp_apply(params["g2m_edge"], edge_feat(g.g2m_edge_index,
                                                    n_grid, n_mesh))
    m = jnp.concatenate([e_g2m, h_grid[g.g2m_edge_index[0]],
                         h_mesh[g.g2m_edge_index[1]]], -1)
    e2 = e_g2m + mlp_apply(params["g2m"]["edge"], m)
    agg = scatter_sum(e2, g.g2m_edge_index, n_mesh)
    h_mesh = h_mesh + mlp_apply(params["g2m"]["node"],
                                jnp.concatenate([h_mesh, agg], -1))
    # processor on the multimesh
    e_mesh = mlp_apply(params["mesh_edge"], edge_feat(g.mesh_edge_index,
                                                      n_mesh, n_mesh))
    for lp in params["layers"]:
        h_mesh, e_mesh = _interaction_block(lp, h_mesh, h_mesh, e_mesh,
                                            g.mesh_edge_index, n_mesh)
    # mesh -> grid decoder block
    e_m2g = mlp_apply(params["m2g_edge"], edge_feat(g.m2g_edge_index,
                                                    n_mesh, n_grid))
    m = jnp.concatenate([e_m2g, h_mesh[g.m2g_edge_index[0]],
                         h_grid[g.m2g_edge_index[1]]], -1)
    e2 = e_m2g + mlp_apply(params["m2g"]["edge"], m)
    agg = scatter_sum(e2, g.m2g_edge_index, n_grid)
    h_grid = h_grid + mlp_apply(params["m2g"]["node"],
                                jnp.concatenate([h_grid, agg], -1))
    return mlp_apply(params["dec"], h_grid)


def gnn_loss(cfg: GNNConfig, params, batch):
    """Node-level loss: classification (int labels) or regression (float)."""
    g = batch["graph"]
    out = gnn_forward(cfg, params, g)
    labels = batch["labels"]
    mask = batch.get("mask")
    if jnp.issubdtype(labels.dtype, jnp.integer):
        logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
        ll = jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        loss = -ll
    else:
        loss = jnp.mean(jnp.square(out.astype(jnp.float32) - labels), -1)
    if mask is not None:
        return (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
