"""Observability layer (DESIGN §8): telemetry planes, frame ring,
flight recorder, exporters.

Pins the four contracts of ``repro.obs``:

* ``telemetry=False`` (the default) is bit-exact with the recorded
  pre-PR engine on both backends — the planes collapse to 1x1 dummies
  and the cycle graph is unchanged;
* ``telemetry=True`` changes no semantics: same counters and values,
  and the FINAL frame of each increment reconciles EXACTLY with the
  scalar counters (cumulative planes reset with ``stat_*``) — on both
  backends and on both drivers (sync-free device loop and traced host
  loop);
* the livelock flight recorder raises a structured
  :class:`LivelockError` carrying the frame log and naming the wedged
  cells/lanes of the known §4.2 hub deadlock;
* the exporters (Chrome trace / congestion heatmap) preserve the
  totals they re-aggregate.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import EngineConfig, StreamingEngine
from repro.core.engine import LivelockError
from repro.core.state import TM_EXEC, TM_HOP, TM_IO
from repro.graph.streams import StreamSpec, hub_edges, make_stream
from repro.obs import (FS_CYCLE, FrameLog, chrome_trace, congestion_heatmap,
                       engine_rates, summarize, wedged_cells, wedged_lanes)
from repro.obs.export import STAGE_NAMES

ONE = np.float32(1.0).view(np.int32)
REF = json.loads((pathlib.Path(__file__).parent
                  / "data" / "pre_lanes_reference.json").read_text())


def _ref_engine(backend, **kw):
    eng = StreamingEngine(
        EngineConfig(backend=backend, **REF["cfg"], **kw), "bfs")
    eng.seed(0, 0.0)
    return eng, make_stream(StreamSpec(**REF["spec"]))


# ---------------- telemetry=False stays bit-exact (both backends) --------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_telemetry_off_bit_exact_vs_pre_pr(backend):
    """With telemetry off (explicit) the engine replays the recorded
    pre-PR fingerprint exactly — the telemetry refactor is free."""
    eng, incs = _ref_engine(backend, telemetry=False)
    rows = []
    for e in incs:
        r = eng.run_increment(e, max_cycles=500_000)
        rows.append(dict(cycles=r.cycles, hops=r.hops, execs=r.execs,
                         stalls=r.stalls, allocs=r.allocs))
        assert r.frames is None
    want = REF["backends"][backend]
    assert rows == want["increments"]
    np.testing.assert_array_equal(eng.values(128), np.array(want["values"]))


# ------------- telemetry=True: same semantics + exact reconcile ----------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_telemetry_on_counters_and_frames_reconcile(backend):
    """Telemetry on: identical counters/values as the fingerprint, and
    every increment's final frame reconciles exactly with its scalar
    counters (DESIGN §8 invariants)."""
    eng, incs = _ref_engine(backend, telemetry=True, frame_ring=16)
    want = REF["backends"][backend]
    for e, w in zip(incs, want["increments"]):
        r = eng.run_increment(e, max_cycles=500_000)
        got = dict(cycles=r.cycles, hops=r.hops, execs=r.execs,
                   stalls=r.stalls, allocs=r.allocs)
        assert got == w
        assert isinstance(r.frames, FrameLog) and len(r.frames) >= 2
        t = r.frames.totals()
        assert t["quiescent"] and t["backlog"] == 0 and t["in_flight"] == 0
        assert (t["hops"], t["execs"], t["stalls"], t["allocs"]) == \
            (r.hops, r.execs, r.stalls, r.allocs)
        # the per-cell planes reconcile with the same counters: every
        # hop/exec/insert is attributed to exactly one cell
        last = r.frames.last()
        assert int(last["cell"][..., TM_HOP].sum()) == r.hops
        assert int(last["cell"][..., TM_EXEC].sum()) == r.execs
        assert int(last["cell"][..., TM_IO].sum()) == len(e)
    np.testing.assert_array_equal(eng.values(128), np.array(want["values"]))


def test_device_loop_frames_match_traced_host_loop():
    """The sync-free device loop and the traced host loop record the
    same frame totals over the full BFS stream (same snapshot schema,
    different drivers)."""
    eng_d, incs = _ref_engine("jnp", telemetry=True, frame_ring=16)
    eng_t, _ = _ref_engine("jnp", telemetry=True, frame_ring=16)
    for e in incs:
        rd = eng_d.run_increment(e, max_cycles=500_000)
        rt = eng_t.run_increment(e, max_cycles=500_000,
                                 collect_traces=True)
        assert rd.frames.totals() == rt.frames.totals()
        np.testing.assert_array_equal(rd.frames.last()["cell"],
                                      rt.frames.last()["cell"])
        np.testing.assert_array_equal(rd.frames.last()["lane"],
                                      rt.frames.last()["lane"])


def test_frame_ring_wraps_and_keeps_newest():
    """A tiny ring on a long increment drops the oldest frames but keeps
    the final (reconciling) frame; deltas() switches to window-only."""
    eng, incs = _ref_engine("jnp", telemetry=True, frame_ring=2)
    r = eng.run_increment(incs[1], max_cycles=500_000)
    assert len(r.frames) == 2 and r.frames.dropped > 0
    assert r.frames.totals()["hops"] == r.hops
    d = r.frames.deltas()
    assert d["cell"].shape[0] == len(r.frames) - 1
    # cumulative planes are monotone, so the in-window delta is >= 0
    assert (d["cell"] >= 0).all() and (d["scal"][:, FS_CYCLE] > 0).all()


# --------------------- livelock flight recorder --------------------------

def _hub_cfg(**kw):
    base = dict(height=8, width=8, n_vertices=128, edge_cap=4,
                ghost_slots=48, queue_cap=20, chan_cap=16, futq_cap=4,
                io_stream_cap=2048, chunk=64, lanes=1)
    base.update(kw)
    return EngineConfig(**base)


def _hub_stream(n=128, degree=200, seed=3):
    e = hub_edges(n, 0, degree, seed=seed)
    return np.concatenate([e, np.full((len(e), 1), ONE, np.int64)],
                          1).astype(np.int32)


def test_flight_recorder_names_wedged_cells():
    """The known §4.2 hub livelock raises LivelockError with frames, and
    the wedge analysis names the hub cell (0,0) — whose action queue is
    full — plus the row-0 lanes feeding it."""
    eng = StreamingEngine(_hub_cfg(telemetry=True, frame_ring=16), "bfs")
    eng.seed(0, 0.0)
    with pytest.raises(LivelockError) as ei:
        eng.run_increment(_hub_stream(), max_cycles=500_000)
    err = ei.value
    assert err.cycle > 0 and err.chunk > 0
    assert isinstance(err.frames, FrameLog) and len(err.frames) >= 2
    cells = wedged_cells(eng.cfg, err.frames)
    lanes = wedged_lanes(eng.cfg, err.frames)
    assert cells, "no wedged cells found at livelock"
    assert (0, 0) in [d["cell"] for d in cells]   # the hub vertex's cell
    hub = next(d for d in cells if d["cell"] == (0, 0))
    assert hub["aq"] > 0 and hub["aq_hiwater"] >= hub["aq"]
    assert lanes, "no wedged lanes found at livelock"
    assert all(e["occ"] > 0 for e in lanes)
    # the rendered report names the machinery for humans too
    assert "flight recorder" in str(err) and "cell (0,0)" in str(err)


def test_livelock_without_telemetry_is_structured_but_frameless():
    """Telemetry off: the detector still raises the structured error
    (catchable without regex), just with no frame log attached."""
    eng = StreamingEngine(_hub_cfg(), "bfs")
    eng.seed(0, 0.0)
    with pytest.raises(LivelockError) as ei:
        eng.run_increment(_hub_stream(), max_cycles=500_000)
    assert ei.value.frames is None
    assert "livelock" in str(ei.value)     # back-compat substring


# ----------------------------- exporters ---------------------------------

def _frames(backend="jnp"):
    eng, incs = _ref_engine(backend, telemetry=True, frame_ring=16)
    r = eng.run_increment(incs[0], max_cycles=500_000)
    return eng.cfg, r


def test_chrome_trace_structure_and_totals():
    cfg, r = _frames()
    tr = chrome_trace(cfg, r.frames)
    evs = tr["traceEvents"]
    assert evs and all(e["ph"] == "C" for e in evs)
    names = {e["name"] for e in evs}
    assert {f"stage/{n}" for n in STAGE_NAMES} <= names
    assert {f"lane/{d}0" for d in "NSWE"} <= names
    # counter deltas sum back to the increment totals
    hops = sum(e["args"]["hop"] for e in evs if e["name"] == "stage/hop")
    execs = sum(e["args"]["exec"] for e in evs if e["name"] == "stage/exec")
    assert (hops, execs) == (r.hops, r.execs)
    # timestamps are machine cycles, monotone per track
    ts = [e["ts"] for e in evs if e["name"] == "stage/hop"]
    assert ts == sorted(ts)


def test_congestion_heatmap_totals_and_report_render():
    cfg, r = _frames()
    heat = congestion_heatmap(cfg, r.frames)
    assert heat["grid"] == [cfg.height, cfg.width]
    assert sum(map(sum, heat["stages"]["hop"])) == r.hops
    assert sum(map(sum, heat["stages"]["exec"])) == r.execs
    assert max(map(max, heat["aq_hiwater"])) > 0
    # the report renderer consumes the dump (satellite: report.py)
    from benchmarks.report import congestion_section
    md = congestion_section(heat)
    assert "message arrivals" in md and "```" in md


def test_engine_rates_and_summarize():
    cfg, r = _frames()
    rates = engine_rates(r.frames)
    assert rates["cycles"] == r.cycles
    assert rates["execs_per_cycle"] == pytest.approx(r.execs / r.cycles)
    assert rates["peak_backlog"] >= 0
    s = summarize([1.0, 2.0, 3.0, 4.0], "ms")
    assert s["n"] == 4 and s["p50"] == pytest.approx(2.5)
    assert s["max"] == 4.0 and s["p99"] <= 4.0
