"""Livelock flight recorder (DESIGN §8).

When the engine's livelock detector fires, the last ``cfg.frame_ring``
frames are already sitting in the device frame ring — the flight
recorder turns them into a per-cell / per-lane "who is wedged" report
instead of the bare sizing-advice exception message.

Wedge analysis over the TRAILING window (default 8 frames = the
livelock detector's ``LIVELOCK_CHUNKS`` no-progress chunks, so startup
activity earlier in the ring cannot mask a late wedge):

* a **cell** is wedged when it still holds work at the final frame
  (action queue, park ring or any outgoing lane non-empty) but made no
  progress over the window — zero action pops and zero flit arrivals;
* a **lane** is wedged when it is occupied at the final frame but won
  zero arbiter grants over the window (all its blocked cycles counted).

The report names the wedged cells with their queue depths and hi-water
marks, and the wedged lanes with their occupancy — the §4.2/§7
diagnosis that previously took a manual host-loop trace session.
"""
from __future__ import annotations

import numpy as np

from repro.core.config import EngineConfig
from repro.core.state import (TM_EXEC, TM_HOP, TM_HW_AQ, TM_HW_PK,
                              TM_L_GRANT)
from repro.obs.frames import FS_CYCLE, FrameLog

_DIR_NAMES = ("N", "S", "W", "E")

# trailing-window length in frames; matches engine.LIVELOCK_CHUNKS (the
# detector guarantees this many final chunks made zero progress) —
# duplicated here as a literal to keep ``flight`` import-light
WEDGE_WINDOW = 8


def _window_start(frames: FrameLog, window: int) -> int:
    return max(0, len(frames) - 1 - window)


def wedged_cells(cfg: EngineConfig, frames: FrameLog,
                 window: int = WEDGE_WINDOW) -> list[dict]:
    """Cells holding work with zero exec/arrival progress over the
    trailing window, sorted by total pending work (descending)."""
    first, last = frames.cell[_window_start(frames, window)], frames.cell[-1]
    prog = ((last[..., TM_EXEC] - first[..., TM_EXEC])
            + (last[..., TM_HOP] - first[..., TM_HOP]))      # [H,W]
    aq, pk = frames.aq_n[-1], frames.pk_n[-1]
    ch = frames.ch_n[-1].sum(axis=(-2, -1))                  # [H,W]
    pending = aq + pk + ch
    wedged = (pending > 0) & (prog == 0)
    out = []
    for r, c in zip(*np.nonzero(wedged)):
        out.append(dict(
            cell=(int(r), int(c)), aq=int(aq[r, c]), pk=int(pk[r, c]),
            ch=int(ch[r, c]),
            aq_hiwater=int(frames.hiw[-1][r, c, TM_HW_AQ]),
            pk_hiwater=int(frames.hiw[-1][r, c, TM_HW_PK])))
    out.sort(key=lambda d: -(d["aq"] + d["pk"] + d["ch"]))
    return out


def wedged_lanes(cfg: EngineConfig, frames: FrameLog,
                 window: int = WEDGE_WINDOW) -> list[dict]:
    """Occupied link lanes that won zero grants over the trailing window."""
    first, last = frames.lane[_window_start(frames, window)], frames.lane[-1]
    grants = last[..., TM_L_GRANT] - first[..., TM_L_GRANT]  # [H,W,4,L]
    occ = frames.ch_n[-1]
    wedged = (occ > 0) & (grants == 0)
    out = []
    for r, c, d, l in zip(*np.nonzero(wedged)):
        out.append(dict(cell=(int(r), int(c)), dir=_DIR_NAMES[int(d)],
                        lane=int(l), occ=int(occ[r, c, d, l])))
    out.sort(key=lambda e: -e["occ"])
    return out


def render_wedge_report(cfg: EngineConfig, frames: FrameLog,
                        max_rows: int = 12) -> str:
    """Human-readable flight-recorder report for the livelock message."""
    cells = wedged_cells(cfg, frames)
    lanes = wedged_lanes(cfg, frames)
    w0 = _window_start(frames, WEDGE_WINDOW)
    cyc = int(frames.scal[-1][FS_CYCLE] - frames.scal[w0][FS_CYCLE])
    lines = [f"flight recorder: trailing {len(frames) - w0} of "
             f"{len(frames)} frames ({cyc} cycles) — "
             f"{len(cells)} wedged cell(s), {len(lanes)} wedged lane(s)"]
    for d in cells[:max_rows]:
        r, c = d["cell"]
        lines.append(
            f"  cell ({r},{c}): aq={d['aq']} pk={d['pk']} ch={d['ch']} "
            f"pending, 0 execs / 0 arrivals over the window "
            f"(hi-water aq={d['aq_hiwater']} pk={d['pk_hiwater']})")
    if len(cells) > max_rows:
        lines.append(f"  ... {len(cells) - max_rows} more wedged cells")
    for e in lanes[:max_rows]:
        r, c = e["cell"]
        lines.append(f"  link ({r},{c})->{e['dir']} lane {e['lane']}: "
                     f"{e['occ']} queued, 0 grants over the window")
    if len(lanes) > max_rows:
        lines.append(f"  ... {len(lanes) - max_rows} more wedged lanes")
    return "\n".join(lines)
