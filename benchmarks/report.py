"""Render EXPERIMENTS.md tables from results/dryrun.json.

  PYTHONPATH=src python -m benchmarks.report [--json results/dryrun.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.1f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def dryrun_table(data, mesh):
    lines = ["| arch | shape | lower(s) | compile(s) | arg GB/dev | "
             "temp GB/dev | collective ops |",
             "|---|---|---|---|---|---|---|"]
    for key in sorted(data):
        r = data[key]
        if r["mesh"] != mesh:
            continue
        if not r.get("ok"):
            lines.append(f'| {r["arch"]} | {r["shape"]} | — | — | — | — | '
                         f'FAILED: {r.get("error", "")[:60]} |')
            continue
        c = r.get("collectives", {}).get("counts", {})
        cs = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                      for k, v in c.items() if v)
        lines.append(
            f'| {r["arch"]} | {r["shape"]} | {r.get("lower_s", 0):.0f} | '
            f'{r.get("compile_s", 0):.0f} | '
            f'{r["mem"]["argument_gb"]:.2f} | {r["mem"]["temp_gb"]:.2f} | '
            f'{cs} |')
    return "\n".join(lines)


def roofline_table(data):
    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
             "dominant | MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for key in sorted(data):
        r = data[key]
        if r["mesh"] != "single" or not r.get("ok"):
            continue
        rf = r["roofline"]
        ur = r.get("useful_ratio")
        lines.append(
            f'| {r["arch"]} | {r["shape"]} | {rf["t_compute"]:.4f} | '
            f'{rf["t_memory"]:.4f} | {rf["t_collective"]:.4f} | '
            f'{rf["dominant"]} | '
            f'{"—" if ur is None else f"{ur:.2f}"} | '
            f'{rf["roofline_fraction"]:.3f} |')
    return "\n".join(lines)


_SHADES = " .:-=+*#%@"


def _ascii_heat(plane):
    """Render an [H,W] int plane as an ASCII heat grid (log-ish shading:
    each cell's count relative to the plane max)."""
    import math
    mx = max((v for row in plane for v in row), default=0)
    lines = []
    for row in plane:
        chars = []
        for v in row:
            if mx == 0 or v == 0:
                chars.append(_SHADES[0])
            else:
                k = math.log1p(v) / math.log1p(mx)
                chars.append(_SHADES[min(9, int(k * 9 + 0.5))])
        lines.append("".join(chars))
    return "\n".join(lines)


def congestion_section(heat: dict) -> str:
    """Markdown render of one ``results/profile/heatmap_*.json`` dump
    (the ``repro.obs.export.congestion_heatmap`` schema)."""
    H, W = heat["grid"]
    out = [f"grid {H}x{W}, lanes={heat['lanes']}, {heat['cycles']} cycles, "
           f"{heat['frames']} frames (dropped={heat['dropped']})", ""]
    for title, plane in (("message arrivals (hop)", heat["stages"]["hop"]),
                         ("action executions", heat["stages"]["exec"]),
                         ("stalls", heat["stages"]["stall"]),
                         ("lane occupancy integral",
                          heat["lane_occ_integral"]),
                         ("lane blocked cycles", heat["lane_blocked"]),
                         ("action-queue hi-water", heat["aq_hiwater"])):
        total = sum(map(sum, plane))
        peak = max(map(max, plane))
        out += [f"**{title}** (total {total}, peak cell {peak})", "```",
                _ascii_heat(plane), "```", ""]
    return "\n".join(out)


def serve_section(rec: dict) -> str:
    """Render ``results/bench_serve.json`` (benchmarks.serve_bench): the
    per-tenant table plus an ASCII latency-percentile bar chart."""
    out = [f"scale={rec['scale']} qbatch={rec['qbatch']} "
           f"batch_cycles={rec['batch_cycles']} "
           f"serial_total={rec['serial_cycles_total']} "
           f"speedup={rec['speedup']}x "
           f"all_exact={rec['all_exact']} deferrals={rec['deferrals']}", ""]
    lat_of = {r["slot"]: r.get("latency_cycles")
              for r in rec.get("receipts", [])}
    out += ["| slot | app | source | serial cycles | latency (cycles) | "
            "exact |", "|---|---|---|---|---|---|"]
    for q in rec["queries"]:
        lat = lat_of.get(q["slot"])
        out.append(f'| {q["slot"]} | {q["app"]} | {q["source"]} | '
                   f'{q["serial_cycles"]} | '
                   f'{"—" if lat is None else lat} | '
                   f'{"yes" if q["exact"] else "NO"} |')
    s = rec.get("latency", {})
    if s.get("n"):
        out += ["", "time-to-quiescence percentiles "
                    f"(n={s['n']}, {s['unit']}):", "```"]
        top = max(s[k] for k in ("p50", "p90", "p99", "max"))
        for k in ("p50", "p90", "p99", "max"):
            bar = "#" * max(1, int(40 * s[k] / max(top, 1)))
            out.append(f"{k:>4} {s[k]:>10.0f} {bar}")
        out.append("```")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--heatmap", default="results/profile/heatmap_jnp.json",
                    help="congestion-heatmap dump (benchmarks.run --profile)")
    ap.add_argument("--serve-json", default="results/bench_serve.json",
                    help="serving-bench record (benchmarks.run --only serve)")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "congestion",
                             "serve"])
    args = ap.parse_args()
    if args.section == "serve":
        rec = json.loads(pathlib.Path(args.serve_json).read_text())
        print(f"### Multi-tenant serving ({args.serve_json})\n")
        print(serve_section(rec))
        return
    if args.section == "congestion":
        heat = json.loads(pathlib.Path(args.heatmap).read_text())
        print(f"### Congestion heatmaps ({args.heatmap})\n")
        print(congestion_section(heat))
        return
    data = json.loads(pathlib.Path(args.json).read_text())
    if args.section in ("all", "dryrun"):
        print("### Dry-run — single pod (16x16 = 256 chips)\n")
        print(dryrun_table(data, "single"))
        print("\n### Dry-run — multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(data, "multi"))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (single pod, per device)\n")
        print(roofline_table(data))


if __name__ == "__main__":
    main()
