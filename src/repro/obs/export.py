"""Telemetry exporters (DESIGN §8).

Two render targets for a :class:`repro.obs.FrameLog`:

* :func:`chrome_trace` — Chrome ``trace_event`` JSON (load in
  ``chrome://tracing`` / Perfetto): one counter track per pipeline
  stage (chip-wide per-chunk activity) and one per virtual lane
  (occupancy + grants + blocked, aggregated over the mesh), with the
  machine cycle as the timebase (1 cycle = 1 "us");
* :func:`congestion_heatmap` — per-cell [H,W] planes of the increment's
  cumulative activity (arrivals, execs, stalls, lane occupancy
  integral, blocked cycles, queue hi-water marks), the JSON dump that
  ``benchmarks/report.py --section congestion`` renders.

Both are pure dict builders over host numpy; ``write_*`` helpers dump
them to JSON files under ``results/profile/``.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core.config import EngineConfig
from repro.core.state import (N_TM_STAGES, TM_HW_AQ, TM_HW_PK, TM_L_BLOCK,
                              TM_L_GRANT, TM_L_OCC)
from repro.obs.frames import FS_CYCLE, FrameLog

# index order matches the TM_* stage constants in core.state
STAGE_NAMES = ("exec", "alloc", "stall", "hop", "stage",
               "park", "unpark", "io", "bcast")
assert len(STAGE_NAMES) == N_TM_STAGES

_DIR_NAMES = ("N", "S", "W", "E")


def chrome_trace(cfg: EngineConfig, frames: FrameLog) -> dict:
    """Chrome ``trace_event`` counter tracks from the frame log.

    Counter semantics: each sample is the PER-CHUNK activity (delta of
    the cumulative plane between consecutive frames), stamped at the
    frame's machine cycle.  Stage tracks sum over the mesh; lane tracks
    sum each ``(direction, lane)`` pair over the mesh so a wedged escape
    lane shows up as a flat-lining ``lane/W0 grants`` counter.
    """
    d = frames.deltas()
    cyc = frames.scal[:, FS_CYCLE]
    if frames.dropped:
        cyc = cyc[1:]                       # deltas() dropped frame 0
    events = []

    def counter(name, ts, args):
        events.append(dict(name=name, ph="C", ts=int(ts), pid=0, tid=0,
                           args={k: int(v) for k, v in args.items()}))

    cell = d["cell"].sum(axis=(1, 2))        # [N, N_TM_STAGES]
    for i, t in enumerate(cyc):
        for s, name in enumerate(STAGE_NAMES):
            counter(f"stage/{name}", t, {name: cell[i, s]})
    lane = d["lane"].sum(axis=(1, 2))        # [N, 4, L, N_TM_LANE]
    occ = frames.ch_n.sum(axis=(1, 2))       # [N, 4, L] instantaneous
    if frames.dropped:
        occ = occ[1:]
    L = lane.shape[2]
    for i, t in enumerate(cyc):
        for dd in range(4):
            for l in range(L):
                counter(f"lane/{_DIR_NAMES[dd]}{l}", t, {
                    "occ": occ[i, dd, l],
                    "grants": lane[i, dd, l, TM_L_GRANT],
                    "blocked": lane[i, dd, l, TM_L_BLOCK]})
    return dict(traceEvents=events, displayTimeUnit="ms",
                metadata=dict(timebase="1 trace us = 1 machine cycle",
                              grid=f"{cfg.height}x{cfg.width}",
                              lanes=cfg.lanes, frames=len(frames),
                              dropped=frames.dropped))


def congestion_heatmap(cfg: EngineConfig, frames: FrameLog) -> dict:
    """Per-cell congestion planes of the increment (final frame's
    cumulative counters), as JSON-ready nested lists."""
    last = frames.last()
    cell, lane, hiw = last["cell"], last["lane"], last["hiw"]
    # cycle span of the log (frame 0 = increment-start baseline)
    cycles = max(1, int(frames.scal[-1][FS_CYCLE]
                        - frames.scal[0][FS_CYCLE]))

    def plane(a):
        return np.asarray(a).astype(int).tolist()

    return dict(
        grid=[cfg.height, cfg.width], lanes=cfg.lanes, cycles=cycles,
        frames=len(frames), dropped=frames.dropped,
        # [H,W] planes
        stages={n: plane(cell[..., i]) for i, n in enumerate(STAGE_NAMES)},
        lane_occ_integral=plane(lane[..., TM_L_OCC].sum(axis=(-2, -1))),
        lane_blocked=plane(lane[..., TM_L_BLOCK].sum(axis=(-2, -1))),
        lane_grants=plane(lane[..., TM_L_GRANT].sum(axis=(-2, -1))),
        aq_hiwater=plane(hiw[..., TM_HW_AQ]),
        pk_hiwater=plane(hiw[..., TM_HW_PK]))


def write_chrome_trace(path, cfg: EngineConfig, frames: FrameLog) -> str:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(chrome_trace(cfg, frames)))
    return str(p)


def write_heatmap(path, cfg: EngineConfig, frames: FrameLog) -> str:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(congestion_heatmap(cfg, frames), indent=1))
    return str(p)
