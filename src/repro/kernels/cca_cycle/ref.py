"""Reference semantics of one megakernel launch, in pure jnp.

``frozen_cycles`` is the single copy of the launch's compute: a
fixed-length ``fori_loop`` of engine cycles that freezes to the identity
once the machine quiesces, so a launch never overshoots the quiescent
state and the final ``cycle`` counter is the exact quiescence cycle.
The Pallas kernel (``kernel.py``) wraps exactly this function between
its VMEM loads and stores — the kernel and the reference cannot drift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.apps import DiffusionApp
from repro.core.config import EngineConfig
from repro.core.engine import cycle_body, quiescent
from repro.core.state import MachineState


def frozen_cycles(cfg: EngineConfig, app: DiffusionApp, st: MachineState,
                  n_cycles: int):
    """Run ``n_cycles`` engine cycles with freeze-at-quiescence.

    Returns ``(state, quiescent_flag, cycles_run)`` where ``cycles_run``
    counts only the non-frozen (actually executed) cycles.
    """
    def body(_, carry):
        s, ran = carry
        done = quiescent(s)
        s2, _ = cycle_body(cfg, app, s)
        s = jax.tree.map(lambda a, b: jnp.where(done, a, b), s, s2)
        return s, ran + (~done).astype(jnp.int32)

    st, ran = jax.lax.fori_loop(0, n_cycles, body, (st, jnp.int32(0)))
    return st, quiescent(st), ran


def cca_cycle_chunk_ref(cfg: EngineConfig, app: DiffusionApp,
                        st: MachineState, n_cycles: int | None = None):
    """Drop-in reference for :func:`repro.kernels.cca_cycle.ops.
    cca_cycle_chunk`: same return convention, no Pallas."""
    n_cycles = cfg.chunk if n_cycles is None else n_cycles
    st, q, ran = frozen_cycles(cfg, app, st, n_cycles)
    return st, jnp.stack([q.astype(jnp.int32), ran])
