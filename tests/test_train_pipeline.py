"""Pipelined LM training path (launch.train + dist.pipeline).

The transformer's layer-stacked params feed ``split_stages`` /
``pipelined_apply`` directly.  One in-process test pins the sequential
fallback (mesh-less CI) to ``lm_loss``; the meshed GPipe schedule needs
its own process (XLA device count locks at first jax init), mirroring
tests/test_pipeline_parallel.py.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np


def _batch(cfg, b, t):
    rng = np.random.default_rng(0)
    return {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (b, t), dtype=np.int32)),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab, (b, t), dtype=np.int32)),
    }


def test_pipeline_loss_fallback_matches_lm_loss():
    from repro.launch.train import PRESETS, make_pipeline_loss
    from repro.models.transformer import init_lm_params, lm_loss

    cfg = PRESETS["lm_pipe"]
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 4, 16)
    want = float(lm_loss(cfg, params, batch))
    got = float(make_pipeline_loss(cfg, 2, None, 4)(params, batch))
    np.testing.assert_allclose(got, want, rtol=1e-3)


def test_train_driver_runs_pipelined():
    from repro.launch.train import PRESETS, train

    _, losses = train(PRESETS["lm_pipe"], steps=1, batch=4, seq=16,
                      ckpt_dir=None, pipeline_stages=2, n_micro=4,
                      log_every=1)
    assert len(losses) == 1 and np.isfinite(losses[0])


MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.train import PRESETS, make_pipeline_loss
    from repro.models.transformer import init_lm_params, lm_loss

    cfg = PRESETS["lm_pipe"]
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(rng.integers(0, cfg.vocab, (8, 16),
                                         dtype=np.int32))
             for k in ("tokens", "targets")}
    want = lm_loss(cfg, params, batch)
    mesh = jax.make_mesh((4,), ("pipe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    loss_fn = make_pipeline_loss(cfg, 4, mesh, 8)
    np.testing.assert_allclose(float(loss_fn(params, batch)),
                               float(want), rtol=1e-3)
    # gradients flow through the ppermute tick schedule
    g = jax.grad(lambda p: loss_fn(p, batch))(params)
    gn = jnp.sqrt(sum(jnp.vdot(x, x)
                      for x in jax.tree.leaves(g))).real
    gref = jax.grad(lambda p: lm_loss(cfg, p, batch))(params)
    gnr = jnp.sqrt(sum(jnp.vdot(x, x)
                       for x in jax.tree.leaves(gref))).real
    np.testing.assert_allclose(float(gn), float(gnr), rtol=5e-2)
    print("TRAIN_PIPE_OK")
""")


def test_train_pipeline_meshed():
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "TRAIN_PIPE_OK" in r.stdout, r.stdout + r.stderr
