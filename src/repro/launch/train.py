"""End-to-end fault-tolerant training driver.

Runs REAL steps (CPU-sized by default; pass --arch/--preset for bigger).
Integrates every production component: deterministic resumable data
pipeline, AdamW, atomic+async checkpointing, straggler watchdog, optional
int8 gradient compression (inter-pod axis), crash-restart resume.

  PYTHONPATH=src python -m repro.launch.train --steps 100 \
      --ckpt-dir /tmp/ckpt --preset lm100m
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import LMBatchSpec, lm_batch
from repro.models.transformer import LMConfig, init_lm_params, lm_loss
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
from repro.optim.compression import (compress_with_feedback, decompress,
                                     init_residuals)
from repro.train.checkpoint import Checkpointer
from repro.train.elastic import StepWatchdog

PRESETS = {
    # ~100M-param model (deliverable b) — run on real accelerators;
    # CPU CI uses lm_tiny.
    "lm100m": LMConfig(name="lm100m", n_layers=12, d_model=768, n_heads=12,
                       n_kv_heads=4, d_ff=2048, vocab=32768, remat=False),
    "lm_tiny": LMConfig(name="lm_tiny", n_layers=2, d_model=128, n_heads=4,
                        n_kv_heads=2, d_ff=256, vocab=512, remat=False,
                        attn_chunk=64),
}


def make_train_step(cfg: LMConfig, opt_cfg: AdamWConfig, compress: bool):
    @jax.jit
    def step(params, opt, residuals, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch))(params)
        if compress:
            # inter-pod gradient path: int8 + error feedback
            comp, residuals = compress_with_feedback(grads, residuals)
            grads = decompress(comp, grads)
        params, opt, gnorm = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, residuals, loss, gnorm
    return step


def train(cfg: LMConfig, steps: int, batch: int, seq: int,
          ckpt_dir: str | None, ckpt_every: int = 50, compress: bool = False,
          watchdog_s: float = 0.0, log_every: int = 10, seed: int = 0):
    opt_cfg = AdamWConfig(total_steps=steps)
    params = init_lm_params(cfg, jax.random.PRNGKey(seed))
    opt = init_adamw(params)
    residuals = init_residuals(params) if compress else \
        jax.tree.map(lambda x: jnp.zeros((), jnp.float32), params)
    bspec = LMBatchSpec(batch=batch, seq_len=seq, vocab=cfg.vocab, seed=seed)
    start = 0
    ck = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ck and ck.latest_step() is not None:
        (params, opt, residuals), extra, start = ck.restore(
            (params, opt, residuals))
        print(f"[train] resumed from step {start} ({extra})")
    step_fn = make_train_step(cfg, opt_cfg, compress)
    wd = StepWatchdog(watchdog_s) if watchdog_s > 0 else None
    losses = []
    t0 = time.time()
    for s in range(start, steps):
        if wd:
            wd.arm(s)
        b = {k: jnp.asarray(v) for k, v in lm_batch(bspec, s).items()}
        params, opt, residuals, loss, gnorm = step_fn(
            params, opt, residuals, b)
        if wd:
            wd.disarm()
        losses.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            dt = time.time() - t0
            print(f"[train] step {s} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} ({dt:.1f}s)")
        if ck and (s + 1) % ckpt_every == 0:
            ck.save_async(s + 1, (params, opt, residuals),
                          extra=dict(loss=float(loss)))
    if ck:
        ck.wait()
        ck.save(steps, (params, opt, residuals),
                extra=dict(loss=losses[-1]))
    return params, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="lm_tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--watchdog-s", type=float, default=0.0)
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    _, losses = train(cfg, args.steps, args.batch, args.seq,
                      args.ckpt_dir, args.ckpt_every, args.compress,
                      args.watchdog_s)
    print(f"[train] done. first loss {losses[0]:.4f} -> "
          f"last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
