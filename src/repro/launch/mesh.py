"""Production mesh construction.

Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod:  2x16x16 = 512 chips ("pod", "data", "model") — the pod axis is
pure data parallelism over DCN; gradient reduction is hierarchical
(reduce-scatter intra-pod over ICI, all-reduce inter-pod).

Defined as a function (NOT a module-level constant) so importing this
module never touches jax device state.
"""
from __future__ import annotations

from repro.dist.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 device unless XLA_FLAGS overrides)."""
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


def dp_axes(mesh) -> tuple:
    """The data-parallel axis group: ('pod','data') when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
