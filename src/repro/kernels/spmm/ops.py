"""Jitted wrapper: full SpMM (gather -> message -> MXU scatter)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmm.kernel import scatter_spmm
from repro.kernels.spmm.ref import scatter_spmm_ref


@functools.partial(jax.jit,
                   static_argnames=("n_nodes", "bn", "be", "interpret"))
def spmm_sorted_coo(x, src, dst, n_nodes, coeff=None, *, bn=128, be=256,
                    interpret=None):
    """A @ X over a COO edge list sorted by dst (the GNN hot path)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    msgs = x[src]
    if coeff is not None:
        msgs = msgs * coeff[:, None]
    return scatter_spmm(msgs, dst, n_nodes, bn=bn, be=be,
                        interpret=interpret)


spmm_reference = jax.jit(scatter_spmm_ref, static_argnames=("n_nodes",))
