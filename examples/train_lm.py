"""End-to-end LM training with the full production substrate:
deterministic pipeline, AdamW, async atomic checkpoints, watchdog,
int8-compressed gradients.  (CPU-sized; --preset lm100m on accelerators.)

  PYTHONPATH=src python examples/train_lm.py
"""
from repro.launch.train import PRESETS, train

params, losses = train(
    PRESETS["lm_tiny"], steps=30, batch=4, seq=64,
    ckpt_dir="/tmp/repro_lm_ckpt", ckpt_every=10,
    compress=True, watchdog_s=300.0, log_every=5)
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
assert losses[-1] < losses[0], "loss should decrease"
