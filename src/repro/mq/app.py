"""Composite query-batched apps (repro.mq, DESIGN §10).

``batch_app`` lifts Q per-slot scalar :class:`DiffusionApp`s into one
composite app whose relax / edge_value / forward-merge act on the whole
``[..., Q]`` value vector.  Each slot keeps its own monotone frame (its
relax direction, edge semiring and neutral element), so a mixed
BFS + SSSP + CC + widest batch rides one diffusion wave: a message that
reaches a vertex relaxes every tenant's slot at once, and slots for which
the payload is the neutral element simply no-op (over-propagation is
sound under monotone relaxation).

The composite stays a frozen dataclass with tuple-valued ``init_val`` /
``fwd_neutral`` so it remains hashable — the engine passes the app as a
jit static argument, and a new slot mix is just a recompile.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.apps import APPS, DiffusionApp


def _stack_relax(slot_apps):
    def relax(vals, incoming):
        # vals, incoming: [..., Q] -> per-slot scalar relax, re-stacked
        outs, chgs = [], []
        for q, a in enumerate(slot_apps):
            nv, ch = a.relax(vals[..., q:q + 1], incoming[..., q])
            outs.append(nv)
            chgs.append(ch)
        return jnp.concatenate(outs, axis=-1), jnp.stack(chgs, axis=-1)
    return relax


def _stack_edge_value(slot_apps):
    def edge_value(v, w):
        # v: [..., Q] source emission, w: [...] edge weight (shared)
        return jnp.stack([a.edge_value(v[..., q], w)
                          for q, a in enumerate(slot_apps)], axis=-1)
    return edge_value


def _stack_propagate(slot_apps):
    def propagate_on_insert(vals):
        # an insert propagates if ANY tenant would propagate; the wave
        # carries the full vector and no-ops on unreached slots
        p = slot_apps[0].propagate_on_insert(vals[..., 0:1])
        for q, a in enumerate(slot_apps[1:], start=1):
            p = p | a.propagate_on_insert(vals[..., q:q + 1])
        return p
    return propagate_on_insert


def _stack_fwd_merge(slot_apps):
    def fwd_merge(fv, inc):
        # per-slot meet of the deferred app-forward register (§4.4)
        return jnp.stack([a.fwd_merge(fv[..., q], inc[..., q])
                          for q, a in enumerate(slot_apps)], axis=-1)
    return fwd_merge


def batch_app(slot_apps, name: str | None = None) -> DiffusionApp:
    """Compose Q per-slot apps into one qbatch=Q :class:`DiffusionApp`.

    ``slot_apps``: sequence of app names (keys of ``core.apps.APPS``) or
    :class:`DiffusionApp` instances, one per query slot.  Every slot app
    must be a scalar app (``n_vals == 1``, ``qbatch == 1``).
    """
    apps = tuple(APPS[a] if isinstance(a, str) else a for a in slot_apps)
    Q = len(apps)
    assert Q >= 1, "batch_app needs at least one slot app"
    for a in apps:
        assert a.n_vals == 1 and a.qbatch == 1, \
            f"slot app {a.name!r} must be a scalar app"
    if Q == 1:
        return apps[0]
    # host-side root combine is per-slot (MQSession passes each slot's
    # combine to engine.values); the composite default only covers
    # whole-vector internal uses, which never mix directions
    return DiffusionApp(
        name=name or ("mq[" + ",".join(a.name for a in apps) + "]"),
        relax=_stack_relax(apps),
        edge_value=_stack_edge_value(apps),
        propagate_on_insert=_stack_propagate(apps),
        init_val=tuple(float(a.init_val) for a in apps),
        n_vals=Q,
        combine=apps[0].combine,
        fwd_merge=_stack_fwd_merge(apps),
        fwd_neutral=tuple(float(a.fwd_neutral) for a in apps),
        qbatch=Q,
        slot_apps=apps,
    )
