"""Host wrapper for the fused cycle megakernel.

``cca_cycle_chunk`` flattens the ``MachineState`` into Pallas operands
(bool leaves ride as int32, the five scalar counters pack into one
``(1, 8)`` SMEM record), launches ``kernel.cycle_megakernel`` with every
input aliased onto its output (the state is updated in place — no
second copy of the machine in HBM), and rebuilds the pytree.

Backend selection mirrors the other kernel dirs: compiled Mosaic on
TPU, ``interpret=True`` everywhere else so CPU CI runs the identical
kernel semantics (Pallas interpret mode discharges the kernel into the
surrounding XLA computation, so the fallback is still jit-compiled —
only the VMEM residency is simulated).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.apps import DiffusionApp
from repro.core.config import EngineConfig
from repro.core.state import MachineState
from repro.kernels.cca_cycle.kernel import (BOOL_LEAVES, IDX_QUIESCENT,
                                            IDX_RAN, N_SCALARS,
                                            SCALAR_LEAVES, cycle_megakernel)

ARRAY_LEAVES = tuple(f for f in MachineState._fields
                     if f not in SCALAR_LEAVES)


def cca_cycle_chunk(cfg: EngineConfig, app: DiffusionApp, st: MachineState,
                    n_cycles: int | None = None, interpret: bool | None = None):
    """Run up to ``n_cycles`` (default ``cfg.chunk``) engine cycles in one
    fused Pallas launch with freeze-at-quiescence.

    Returns ``(state, counters)`` — ``counters`` is int32
    ``[quiescent_at_end, cycles_run]`` read from the kernel's SMEM
    record.  Traceable: safe to call inside jit / ``lax.while_loop``
    (the engine's sync-free driver does exactly that).
    """
    n_cycles = cfg.chunk if n_cycles is None else n_cycles
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    arrs = [getattr(st, name).astype(jnp.int32)
            if name in BOOL_LEAVES else getattr(st, name)
            for name in ARRAY_LEAVES]
    scal = jnp.stack(
        [getattr(st, name) for name in SCALAR_LEAVES]
        + [jnp.int32(0)] * (N_SCALARS - len(SCALAR_LEAVES))).reshape(1, -1)

    kernel = functools.partial(cycle_megakernel, cfg, app, n_cycles,
                               ARRAY_LEAVES)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    vmem = pl.BlockSpec(memory_space=pltpu.ANY if interpret else pltpu.VMEM)
    outs = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct(scal.shape, jnp.int32)]
        + [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrs],
        in_specs=[smem] + [vmem] * len(arrs),
        out_specs=[smem] + [vmem] * len(arrs),
        input_output_aliases={i: i for i in range(1 + len(arrs))},
        interpret=interpret,
    )(scal, *arrs)

    scal_o, arr_o = outs[0], outs[1:]
    leaves = dict(zip(ARRAY_LEAVES, arr_o))
    for name in BOOL_LEAVES:
        leaves[name] = leaves[name].astype(bool)
    for i, name in enumerate(SCALAR_LEAVES):
        leaves[name] = scal_o[0, i]
    return MachineState(**leaves), scal_o[0, IDX_QUIESCENT:IDX_RAN + 1]
