"""Batched serving demo: continuous-batching decode loop with ragged
per-slot cache lengths.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve
from repro.launch.train import PRESETS

tokens, tput, metrics = serve(PRESETS["lm_tiny"], n_requests=6, batch=3,
                              prompt_len=8, gen_len=8, max_len=64)
assert all(len(v) > 0 for v in tokens.values())
assert metrics["n"] > 0 and metrics["p99"] >= metrics["p50"]
print(f"served {len(tokens)} requests at {tput:.1f} tok/s aggregate "
      f"(decode p50 {metrics['p50']:.2f}ms, p99 {metrics['p99']:.2f}ms)")
