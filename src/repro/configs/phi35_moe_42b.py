"""--arch phi3.5-moe-42b-a6.6b (exact published config; see lm_archs.py)."""
from repro.configs.lm_archs import PHI35_MOE as CONFIG
from repro.configs.registry import get

BUNDLE = get("phi3.5-moe-42b-a6.6b")
SHAPES = {s.name: s for s in BUNDLE.shapes}
smoke = BUNDLE.smoke
