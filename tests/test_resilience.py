"""Resilience layer (DESIGN §9): deterministic fault injection with
detection + repair, durable checkpoint/restore (kill-and-resume), and
self-healing livelock recovery.

The exactness bar is the same as everywhere else in this repo: a faulty
run must converge to the NetworkX-exact values (via the repair pass),
kill-and-resume must be BIT-exact with the uninterrupted run on both
backends, and ``faults=None`` / ``recover=None`` must leave the engine
bit-identical to the pre-resilience driver (pinned by the fingerprint
tests in test_lanes / test_engine, which run this same code with the
resilience knobs off).
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import EngineConfig, StreamingEngine
from repro.core.engine import LivelockError
from repro.core.msg import OP_APP, OP_REPAIR, N_OPS, make_msg, msg_seal, seal_msg
from repro.core.reference import bfs_levels
from repro.graph.streams import hub_edges
from repro.resilience import (FLT_BLACKOUT, FLT_CORRUPT, FLT_DROP, FLT_DUP,
                              FaultPlan, RecoveryPolicy, config_fingerprint,
                              fault_hash16, migrate_state)
from repro.train.checkpoint import Checkpointer

ONE = np.float32(1.0).view(np.int32)
BACKENDS = ("jnp", "pallas")


def _hub_stream(n=256, degree=120, seed=3):
    e = hub_edges(n, 0, degree, seed=seed)
    return np.concatenate([e, np.full((len(e), 1), ONE, np.int64)],
                          1).astype(np.int32)


def _cfg(**kw):
    base = dict(height=8, width=8, n_vertices=256, edge_cap=8,
                ghost_slots=24, queue_cap=32, chan_cap=16, chunk=64,
                lanes=2, max_cycles=200_000, backend="jnp", telemetry=True)
    base.update(kw)
    return EngineConfig(**base)


def _run(cfg, edges, **kw):
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    res = eng.run_increment(edges, **kw)
    return eng, res


# ------------------------- fault plan mechanics -------------------------

def test_fault_hash_deterministic_and_uniform():
    import jax.numpy as jnp
    cyc = jnp.arange(512)
    a = np.asarray(fault_hash16(7, cyc, 13, 1))
    b = np.asarray(fault_hash16(7, cyc, 13, 1))
    np.testing.assert_array_equal(a, b)          # same inputs, same bits
    assert a.min() >= 0 and a.max() < 65536
    # decisions decorrelate across salt, link and seed
    assert not np.array_equal(a, np.asarray(fault_hash16(7, cyc, 13, 2)))
    assert not np.array_equal(a, np.asarray(fault_hash16(7, cyc, 14, 1)))
    assert not np.array_equal(a, np.asarray(fault_hash16(8, cyc, 13, 1)))
    # a 5% threshold admits roughly 5% of a long window (static rate)
    frac = (a < int(0.05 * 65536)).mean()
    assert 0.01 < frac < 0.12


def test_fault_plan_validation():
    plan = FaultPlan(seed=1, drop_rate=0.5)
    assert plan.drop_thr == int(0.5 * 65536)
    s = plan.safe()
    assert s.drop_thr == 0 and s.blackouts == ()
    with pytest.raises(AssertionError):
        _cfg(faults=FaultPlan(drop_rate=1.5)).validate()
    with pytest.raises(AssertionError):   # blackout cell off the grid
        _cfg(faults=FaultPlan(blackouts=((9, 0, 2, 0, 4),))).validate()


def test_repair_op_and_seal():
    assert OP_REPAIR < N_OPS
    m = make_msg(OP_APP, np.int32(37), np.int32(-123456789))
    sealed = np.asarray(seal_msg(m))
    assert sealed[4] == np.asarray(msg_seal(m))
    # any single bit flip in the payload words breaks the seal
    bad = sealed.copy()
    bad[2] ^= 1 << 11
    assert np.asarray(msg_seal(bad)) != bad[4]


# ------------------- injected faults, exact convergence -------------------

def test_zero_rate_plan_bit_exact():
    edges = _hub_stream()
    e0, r0 = _run(_cfg(), edges)
    e1, r1 = _run(_cfg(faults=FaultPlan(seed=7)), edges)
    assert r1.cycles == r0.cycles
    np.testing.assert_array_equal(e1.values(), e0.values())
    np.testing.assert_array_equal(np.asarray(e1.state.vals),
                                  np.asarray(e0.state.vals))
    assert np.asarray(e1.state.flt).sum() == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_faulty_stream_converges_exact(backend):
    """Seeded drop+dup+corrupt on the hub stream: messages demonstrably
    lost, end state still NetworkX-exact via the §9 repair pass."""
    edges = _hub_stream()
    ref = bfs_levels(256, edges[:, :2], source=0)
    plan = FaultPlan(seed=7, drop_rate=0.05, dup_rate=0.03,
                     corrupt_rate=0.02)
    eng, _ = _run(_cfg(backend=backend, faults=plan), edges)
    flt = np.asarray(eng.state.flt)
    assert flt[FLT_DROP] > 0 and flt[FLT_DUP] > 0 and flt[FLT_CORRUPT] > 0
    np.testing.assert_array_equal(eng.values(), ref)


def test_backends_bit_exact_under_faults():
    """The injected hazards are part of the cycle semantics: both
    backends must take the SAME faults and land on the same state."""
    edges = _hub_stream()
    plan = FaultPlan(seed=7, drop_rate=0.05, dup_rate=0.03,
                     corrupt_rate=0.02)
    ej, _ = _run(_cfg(backend="jnp", faults=plan), edges)
    ep, _ = _run(_cfg(backend="pallas", faults=plan), edges)
    np.testing.assert_array_equal(np.asarray(ej.state.flt),
                                  np.asarray(ep.state.flt))
    np.testing.assert_array_equal(np.asarray(ej.state.vals),
                                  np.asarray(ep.state.vals))


def test_blackout_is_lossless_delay():
    """A link blackout only delays traffic (senders retry): messages hit
    the dead window but nothing is lost, so no repair is needed and the
    values are exact without any OP_REPAIR traffic."""
    edges = _hub_stream()
    ref = bfs_levels(256, edges[:, :2], source=0)
    # hub vid 0 lives at cell (0,0): its inbound row-0 W links carry the
    # flood, so blacking them out early is guaranteed to be exercised
    plan = FaultPlan(seed=7, blackouts=((0, 1, 2, 0, 64), (0, 2, 2, 0, 64)))
    eng, res = _run(_cfg(faults=plan), edges)
    flt = np.asarray(eng.state.flt)
    assert flt[FLT_BLACKOUT] > 0
    assert flt[FLT_DROP] == 0 and flt[FLT_CORRUPT] == 0
    assert res.execs == int(np.asarray(eng.state.stat_exec))
    np.testing.assert_array_equal(eng.values(), ref)


def test_duplicates_are_idempotent():
    """Duplicate delivery alone (no loss) must not perturb the fixpoint:
    monotone relaxation absorbs replays."""
    edges = _hub_stream()
    ref = bfs_levels(256, edges[:, :2], source=0)
    eng, _ = _run(_cfg(faults=FaultPlan(seed=11, dup_rate=0.08)), edges)
    flt = np.asarray(eng.state.flt)
    assert flt[FLT_DUP] > 0 and flt[FLT_DROP] == 0
    np.testing.assert_array_equal(eng.values(), ref)


def test_faulty_multi_increment_stream():
    """Faults + repair across several increments of one growing graph."""
    edges = _hub_stream()
    ref = bfs_levels(256, edges[:, :2], source=0)
    plan = FaultPlan(seed=3, drop_rate=0.04, corrupt_rate=0.02)
    eng = StreamingEngine(_cfg(faults=plan), "bfs")
    eng.seed(0, 0.0)
    for lo, hi in ((0, 150), (150, 300), (300, len(edges))):
        eng.run_increment(edges[lo:hi])
    assert eng.stream_pos == 3
    np.testing.assert_array_equal(eng.values(), ref)


# ------------------- durable state: kill-and-resume -------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_kill_and_resume_bit_exact(backend, tmp_path):
    """Checkpoint at an increment boundary, throw the engine away,
    restore, replay the tail: every state leaf bit-equal to the
    uninterrupted run."""
    edges = _hub_stream()
    incs = [edges[:200], edges[200:350], edges[350:]]
    cfg = _cfg(backend=backend)

    ref = StreamingEngine(cfg, "bfs")
    ref.seed(0, 0.0)
    for inc in incs:
        ref.run_increment(inc)

    ck = Checkpointer(tmp_path)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    for inc in incs[:2]:
        eng.run_increment(inc, ckpt=ck)   # async boundary saves
    eng.checkpoint(ck)                    # boundary after increment 2
    del eng                               # "kill -9"

    res = StreamingEngine.restore(cfg, "bfs", Checkpointer(tmp_path))
    assert res.stream_pos == 2
    res.run_increment(incs[2])
    assert res.totals == ref.totals
    assert res.total_cycles == ref.total_cycles
    for name, a, b in zip(res.state._fields, res.state, ref.state):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state leaf '{name}' diverged across kill-and-resume")


def test_checkpoint_roundtrip_all_leaves(tmp_path):
    """Property check over the full pytree: every leaf (including the
    bool masks and int32 scalars) survives the npz round trip with
    dtype, shape and bits intact; checksum tampering is caught."""
    edges = _hub_stream()
    eng, _ = _run(_cfg(faults=FaultPlan(seed=1, drop_rate=0.02)), edges)
    ck = Checkpointer(tmp_path)
    eng.checkpoint(ck)
    res = StreamingEngine.restore(_cfg(faults=FaultPlan(seed=1,
                                                        drop_rate=0.02)),
                                  "bfs", ck)
    for name, a, b in zip(eng.state._fields, eng.state, res.state):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype, name
        assert a.shape == b.shape, name
        np.testing.assert_array_equal(a, b, err_msg=name)
    # config fingerprint gates the restore
    with pytest.raises(ValueError, match="config"):
        StreamingEngine.restore(_cfg(), "bfs", ck)
    # flip one byte in a shard: tampering is caught — by the manifest
    # checksum, or earlier by the zip container's own CRC
    import zipfile
    shard = next((tmp_path / "step_1").glob("shard_*.npz"))
    blob = bytearray(shard.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    shard.write_bytes(bytes(blob))
    with pytest.raises((IOError, ValueError, zipfile.BadZipFile)):
        StreamingEngine.restore(_cfg(faults=FaultPlan(seed=1,
                                                      drop_rate=0.02)),
                                "bfs", Checkpointer(tmp_path))


def test_restore_sharded_on_fake_mesh():
    """Restore a checkpoint under ``cca_state_shardings`` on 8 fake host
    devices (4x2 mesh) and finish the stream there: values exact
    (subprocess — XLA device count locks at first jax init)."""
    script = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core import EngineConfig, StreamingEngine
        from repro.core.reference import bfs_levels
        from repro.dist.compat import AxisType, make_mesh
        from repro.dist.sharding import cca_state_shardings
        from repro.graph.streams import hub_edges
        from repro.train.checkpoint import Checkpointer

        ONE = np.float32(1.0).view(np.int32)
        e = hub_edges(256, 0, 120, seed=3)
        edges = np.concatenate(
            [e, np.full((len(e), 1), ONE, np.int64)], 1).astype(np.int32)
        cfg = EngineConfig(height=8, width=8, n_vertices=256, edge_cap=8,
                           ghost_slots=24, queue_cap=32, chan_cap=16,
                           chunk=64, lanes=2, max_cycles=200000)
        with tempfile.TemporaryDirectory() as d:
            eng = StreamingEngine(cfg, "bfs")
            eng.seed(0, 0.0)
            eng.run_increment(edges[:250])
            eng.checkpoint(Checkpointer(d))

            mesh = make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
            sh = cca_state_shardings(mesh, jax.eval_shape(lambda: eng.state))
            res = StreamingEngine.restore(cfg, "bfs", Checkpointer(d),
                                          shardings=sh)
            assert res.state.vals.sharding == sh.vals
            res.run_increment(edges[250:])
            eng.run_increment(edges[250:])
            np.testing.assert_array_equal(res.values(), eng.values())
            np.testing.assert_array_equal(
                res.values(), bfs_levels(256, edges[:, :2], source=0))
        print("SHARDED_RESTORE_OK")
    """)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "SHARDED_RESTORE_OK" in r.stdout, r.stdout + r.stderr


# ---------------------- Checkpointer satellite fixes ----------------------

def test_checkpointer_async_error_surfaces(tmp_path):
    """An exception on the writer thread must re-raise from wait(), not
    vanish (a silently-missing checkpoint defeats the whole layer)."""
    ck = Checkpointer(tmp_path / "ck")
    ck.dir = tmp_path / "ck" / "not_a_dir" / "sub"
    (tmp_path / "ck" / "not_a_dir").write_text("file, not dir")
    ck.save_async(0, dict(x=np.arange(4)))
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        ck.wait()
    ck.wait()                  # exception is consumed, not re-raised twice


def test_checkpointer_stale_tmp_cleanup(tmp_path):
    stale = tmp_path / "step_5.tmp"
    stale.mkdir(parents=True)
    (stale / "shard_0.npz").write_bytes(b"garbage")
    ck = Checkpointer(tmp_path)
    assert not stale.exists()
    assert ck.all_steps() == []


# ------------------- livelock recovery (self-healing) -------------------

def _wedge_cfg(**kw):
    # the pinned §4.2 hub wedge from test_lanes: lanes=1 + degree-200 hub
    base = dict(height=8, width=8, n_vertices=128, edge_cap=4,
                ghost_slots=48, queue_cap=20, chan_cap=16, futq_cap=4,
                chunk=64, lanes=1, max_cycles=200_000, telemetry=True)
    base.update(kw)
    return EngineConfig(**base)


def test_livelock_recovery_escalates_lanes():
    """The known lanes=1 hub wedge completes via escalation: restore the
    boundary snapshot, retry with lanes+1, keep the relieved config."""
    edges = _hub_stream(n=128, degree=200, seed=3)
    eng = StreamingEngine(_wedge_cfg(), "bfs")
    eng.seed(0, 0.0)
    eng.run_increment(edges, recover=RecoveryPolicy(max_attempts=2))
    assert eng.cfg.lanes == 2                      # degraded gracefully
    np.testing.assert_array_equal(
        eng.values(), bfs_levels(128, edges[:, :2], source=0))
    assert len(eng.recovery_log) == 1
    entry = eng.recovery_log[0]
    assert entry["lanes"] == 1 and entry["escalated_to"]["lanes"] == 2
    assert entry["backoff_s"] == 0.0
    assert "livelock" in entry["wedge"]
    assert "wedged cell" in entry["wedge"]         # flight-recorder report


def test_recovery_budget_exhausted_reraises():
    """A policy that never relieves anything must exhaust its budget and
    re-raise with the attempt log attached."""
    edges = _hub_stream(n=128, degree=200, seed=3)
    eng = StreamingEngine(_wedge_cfg(), "bfs")
    eng.seed(0, 0.0)
    policy = RecoveryPolicy(max_attempts=1, lanes_step=0, queue_cap_step=0)
    with pytest.raises(LivelockError, match="recovery budget exhausted"):
        eng.run_increment(edges, recover=policy)
    assert len(eng.recovery_log) == 2              # initial try + 1 retry
    assert [e["attempt"] for e in eng.recovery_log] == [0, 1]


def test_migrate_state_rejects_mid_increment_snapshot():
    from repro.core.apps import APPS
    from repro.core.ingest import load_stream
    cfg = _cfg()
    eng = StreamingEngine(cfg, "bfs")
    st, _ = load_stream(eng.cfg, eng.state, _hub_stream()[:8])
    with pytest.raises(ValueError, match="not an increment boundary"):
        migrate_state(eng.cfg, APPS["bfs"], st)


def test_recovery_policy_escalation_is_validated():
    cfg = _wedge_cfg()
    pol = RecoveryPolicy(lanes_step=1, queue_cap_step=4)
    c2 = pol.escalate(cfg, 2)
    assert c2.lanes == 3 and c2.queue_cap == 28
    assert config_fingerprint(c2) != config_fingerprint(cfg)


# ----------------------- ingest guard (backpressure) -----------------------

def test_ingest_guard_throttles_under_pressure():
    """tm_hiw within the reserve band of queue_cap halves the admission
    budget; a calm fabric doubles it back (AIMD)."""
    import jax.numpy as jnp
    cfg = _cfg(ingest_guard=True)
    eng = StreamingEngine(cfg, "bfs")
    cap = eng.cfg.io_cells * eng.cfg.io_stream_cap
    ceiling = eng.cfg.queue_cap - eng.cfg.aq_reserve - eng.cfg.sys_reserve
    eng.state = eng.state._replace(
        tm_hiw=eng.state.tm_hiw.at[0, 0, 0].set(jnp.int32(ceiling)))
    eng._update_ingest_budget()
    assert eng._ingest_budget == cap // 2
    eng._update_ingest_budget()
    assert eng._ingest_budget == cap // 4
    eng.state = eng.state._replace(tm_hiw=jnp.zeros_like(eng.state.tm_hiw))
    eng._update_ingest_budget()
    assert eng._ingest_budget == cap // 2           # additive... doubling back
    assert eng._ingest_limit() == cap // 2


def test_ingest_guard_stream_still_exact():
    """With the guard throttling admission the stream takes more spill
    passes but the fixpoint is unchanged."""
    edges = _hub_stream()
    ref = bfs_levels(256, edges[:, :2], source=0)
    eng = StreamingEngine(_cfg(ingest_guard=True, io_stream_cap=32), "bfs")
    eng.seed(0, 0.0)
    eng.run_increment(edges)
    np.testing.assert_array_equal(eng.values(), ref)
    assert eng._ingest_budget is not None


def test_ingest_guard_requires_telemetry():
    with pytest.raises(AssertionError, match="ingest_guard"):
        _cfg(ingest_guard=True, telemetry=False).validate()
