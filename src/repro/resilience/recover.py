"""Livelock recovery: boundary snapshots, config escalation, migration.

A livelock (DESIGN §4.2) is a *sizing* failure — the workload's message
dependency depth exceeded the buffer budget — so retrying the identical
configuration deterministically wedges again.  The recovery protocol
(DESIGN §9) therefore escalates: restore the last increment-boundary
state, re-run the increment under a relieved config (more virtual lanes,
then a deeper action queue), with exponential backoff between attempts
and the flight-recorder wedge report logged per attempt
(``StreamingEngine.recovery_log``).

Escalation changes ``lanes``/``queue_cap``, which changes the channel /
park / action-queue leaf *shapes* — the boundary state cannot be loaded
verbatim.  :func:`migrate_state` exploits that an increment boundary is
*quiescent* (every queue, channel, park ring, future queue and active
register is empty — that is the definition of quiescence): only the
durable storage leaves carry information, and their shapes are invariant
under lanes/queue_cap relief, so migration is a straight copy into a
fresh ``init_state`` of the new config.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.state import MachineState, init_state

# Leaves that carry durable information at a quiescent boundary; every
# other leaf is provably empty/zero there (see quiescent()) or is a
# counter the increment restart resets anyway.  Shapes depend only on
# the grid/slot geometry, never on lanes/queue_cap — asserted below.
STORAGE_LEAVES = ("vals", "nedges", "edst", "ew", "gaddr", "gstate",
                  "rhz_on", "rstate", "nfree", "arot")


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Retry policy for ``run_increment(recover=...)``.

    Attempt ``k`` (1-based) re-runs the increment from the boundary
    snapshot under ``lanes = base + k * lanes_step`` and ``queue_cap =
    base + k * queue_cap_step``, after sleeping ``backoff_s * 2**(k-1)``
    seconds.  After ``max_attempts`` retries the original
    :class:`LivelockError` is re-raised, augmented with the attempt log.
    """
    max_attempts: int = 3
    backoff_s: float = 0.0
    lanes_step: int = 1
    queue_cap_step: int = 0

    def escalate(self, base_cfg, k: int):
        """The attempt-``k`` relief config derived from ``base_cfg``."""
        kw = {}
        if self.lanes_step:
            kw["lanes"] = base_cfg.lanes + k * self.lanes_step
        if self.queue_cap_step:
            kw["queue_cap"] = base_cfg.queue_cap + k * self.queue_cap_step
        new = dataclasses.replace(base_cfg, **kw)
        new.validate()
        return new


def assert_boundary(st: MachineState) -> None:
    """Raise unless ``st`` is a quiescent increment boundary (the only
    state from which :func:`migrate_state` is sound)."""
    pending = {
        "action queues": int(np.sum(np.asarray(st.aq_n))),
        "channels": int(np.sum(np.asarray(st.ch_n))),
        "park rings": int(np.sum(np.asarray(st.pk_n))),
        "future queues": int(np.sum(np.asarray(st.fq_n))),
        "active actions": int(np.sum(np.asarray(st.cvalid))),
        "coalesced forwards": int(np.sum(np.asarray(st.fwd_pending))),
        "io stream": int(np.sum(np.asarray(st.io_n) - np.asarray(st.io_pos))),
    }
    busy = {k: v for k, v in pending.items() if v}
    if busy:
        raise ValueError(
            "recovery snapshot is not an increment boundary — migration "
            f"is only sound at quiescence (pending work: {busy})")


def migrate_state(new_cfg, app, snapshot: MachineState,
                  strict: bool = True) -> MachineState:
    """Carry a quiescent boundary ``snapshot`` into a fresh machine of
    ``new_cfg`` (typically an escalated lanes/queue_cap relief config).

    Copies only :data:`STORAGE_LEAVES`; queues/channels/registers start
    empty (they *were* empty — quiescence) and counters/telemetry reset
    with the increment restart.
    """
    if strict:
        assert_boundary(snapshot)
    fresh = init_state(new_cfg, init_vals=app.init_val,
                       fwd_init=app.fwd_neutral)
    moved = {}
    for name in STORAGE_LEAVES:
        src = np.asarray(getattr(snapshot, name))
        dst = getattr(fresh, name)
        if src.shape != dst.shape:
            raise ValueError(
                f"cannot migrate leaf '{name}': shape {src.shape} -> "
                f"{dst.shape}; escalation may only change lanes / "
                "queue_cap-class capacities, not the grid or slot layout")
        moved[name] = jnp.asarray(src).astype(dst.dtype)
    return fresh._replace(cycle=jnp.asarray(np.asarray(snapshot.cycle),
                                            jnp.int32), **moved)
