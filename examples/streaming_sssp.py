"""Beyond-paper: streaming dynamic SSSP and connected components on the
same message-driven engine (the paper's §6 future work: "more complex
message-driven streaming dynamic algorithms").

  PYTHONPATH=src python examples/streaming_sssp.py
"""
import numpy as np

from repro.core import EngineConfig, StreamingEngine
from repro.core.reference import cc_labels, sssp_dists

N = 256
rng = np.random.default_rng(7)
cfg = EngineConfig(height=8, width=8, n_vertices=N, edge_cap=4,
                   ghost_slots=64, io_stream_cap=8192)

# ---------------- streaming SSSP ----------------
src = rng.integers(0, N, 2000)
dst = rng.integers(0, N, 2000)
keep = src != dst
w = rng.integers(1, 10, keep.sum()).astype(np.float32)
edges = np.stack([src[keep], dst[keep], w.view(np.int32)], 1).astype(np.int32)

eng = StreamingEngine(cfg, "sssp")
eng.seed(0, 0.0)
for chunk in np.array_split(edges, 4):       # stream in 4 increments
    r = eng.run_increment(chunk)
    print(f"sssp increment: {len(chunk)} edges, {r.cycles} cycles")
want = sssp_dists(N, edges[:, :2], w, 0)
got = eng.values(N)
assert np.allclose(got, want), "SSSP mismatch"
print(f"streaming SSSP verified (mean dist "
      f"{got[got < 1e9].mean():.2f}).")

# ---------------- streaming connected components ----------------
e2 = np.concatenate([edges[:, :2], edges[:, 1::-1]], 0)  # symmetric
one = np.float32(1.0).view(np.int32)
e2 = np.concatenate([e2, np.full((len(e2), 1), one)], 1).astype(np.int32)
eng = StreamingEngine(cfg, "cc")
for v in range(N):
    eng.seed(v, float(v))
r = eng.run_increment(e2)
want = cc_labels(N, edges[:, :2])
assert (eng.values(N) == want).all(), "CC mismatch"
print(f"streaming CC verified ({len(np.unique(want))} components, "
      f"{r.cycles} cycles).")
