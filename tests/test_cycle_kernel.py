"""Fused Pallas cycle-megakernel parity (DESIGN §6).

Mirrors test_dist_cca_parity: the ``backend="pallas"`` engine
(interpret mode on CPU) must be BIT-EXACT per state leaf against the
``backend="jnp"`` engine over a full BFS-to-quiescence stream — plus
the sync-free driver equivalences (``collect_traces=False`` totals ==
traced totals) and identical livelock-detector behaviour on both
backends.
"""
import jax
import numpy as np
import pytest

from repro.core import EngineConfig, StreamingEngine
from repro.core.apps import BFS
from repro.core.ingest import load_stream
from repro.core.reference import bfs_levels
from repro.graph.streams import StreamSpec, make_stream
from repro.kernels.cca_cycle.ops import cca_cycle_chunk
from repro.kernels.cca_cycle.ref import cca_cycle_chunk_ref

ONE = np.float32(1.0).view(np.int32)


def small_cfg(**kw):
    base = dict(height=8, width=8, n_vertices=128, edge_cap=4,
                ghost_slots=32, queue_cap=32, chan_cap=8, futq_cap=8,
                io_stream_cap=2048, chunk=64)
    base.update(kw)
    return EngineConfig(**base)


def assert_states_equal(sa, sb, ctx=""):
    for name, a, b in zip(sa._fields, sa, sb):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"state leaf '{name}' diverged {ctx}")


def run_bfs(cfg, incs, **kw):
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    rs = [eng.run_increment(e, max_cycles=500_000, **kw) for e in incs]
    return eng, rs


def test_megakernel_chunk_bit_exact_vs_ref():
    """One pallas_call (interpret) == the pure-jnp reference chunk, per
    state leaf and per SMEM counter, chunk by chunk to quiescence."""
    rng = np.random.default_rng(0)
    E = 160
    edges = np.stack([rng.integers(0, 64, E), rng.integers(0, 64, E),
                      np.full(E, ONE)], 1).astype(np.int32)
    cfg = small_cfg(n_vertices=64, ghost_slots=16, io_stream_cap=256,
                    chunk=32)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    cfg = eng.cfg
    st, spill = load_stream(cfg, eng.state, edges)
    assert len(spill) == 0
    fk = jax.jit(lambda s: cca_cycle_chunk(cfg, BFS, s, interpret=True))
    fr = jax.jit(lambda s: cca_cycle_chunk_ref(cfg, BFS, s))
    sk, sr = st, st
    for i in range(70):
        sk, ck = fk(sk)
        sr, cr = fr(sr)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr),
                                      err_msg=f"counters, chunk {i}")
        assert_states_equal(sk, sr, f"(kernel vs ref, chunk {i})")
        if bool(np.asarray(ck)[0]):
            break
    assert bool(np.asarray(ck)[0]), "stream did not quiesce in 70 chunks"
    eng.state = sk
    np.testing.assert_array_equal(eng.values(64), bfs_levels(64, edges, 0))


def test_backend_bit_exact_full_stream():
    """backend="pallas" engine == backend="jnp" engine, bit-exact per
    state leaf over a multi-increment BFS stream, identical cycle counts
    and totals, and both exactly NetworkX."""
    spec = StreamSpec(n_vertices=128, n_edges=768, increments=3, seed=7)
    incs = make_stream(spec)
    want = bfs_levels(128, np.concatenate(incs), 0)
    engines, cycles = {}, {}
    for backend in ("jnp", "pallas"):
        eng, rs = run_bfs(small_cfg(backend=backend, chunk=128), incs)
        np.testing.assert_array_equal(eng.values(128), want)
        engines[backend] = eng
        cycles[backend] = [r.cycles for r in rs]
    assert cycles["jnp"] == cycles["pallas"]
    assert engines["jnp"].totals == engines["pallas"].totals
    assert_states_equal(engines["jnp"].state, engines["pallas"].state,
                        "(jnp vs pallas backend)")


def test_backend_parity_rhizome_cap():
    """rhizome_cap > 1 (multi-root protocol incl. OP_LINK_RHIZOME /
    OP_RHIZOME_FWD) behaves identically on both backends."""
    hub = np.array([(0, i, ONE) for i in range(1, 41)], np.int32)
    engines = {}
    for backend in ("jnp", "pallas"):
        cfg = small_cfg(n_vertices=64, ghost_slots=16, futq_cap=4,
                        rhizome_cap=4, backend=backend)
        eng, _ = run_bfs(cfg, [hub])
        np.testing.assert_array_equal(eng.values(64),
                                      bfs_levels(64, hub, 0))
        engines[backend] = eng
    assert (engines["jnp"].vertex_object_stats()
            == engines["pallas"].vertex_object_stats())
    assert_states_equal(engines["jnp"].state, engines["pallas"].state,
                        "(rhizome_cap=4)")


def test_collect_traces_equivalence():
    """The sync-free fast path returns the same IncrementResult totals
    and final state as the traced host loop; only the per-cycle traces
    differ (empty vs length == cycles)."""
    spec = StreamSpec(n_vertices=128, n_edges=768, increments=3, seed=11)
    incs = make_stream(spec)
    fast, rf = run_bfs(small_cfg(), incs)                  # default: fast
    traced, rt = run_bfs(small_cfg(), incs, collect_traces=True)
    for a, b in zip(rf, rt):
        assert (a.cycles, a.hops, a.execs, a.stalls, a.allocs) \
            == (b.cycles, b.hops, b.execs, b.stalls, b.allocs)
        assert len(a.active_per_cycle) == 0
        assert len(a.in_flight_per_cycle) == 0
        assert len(b.active_per_cycle) == b.cycles
    assert fast.totals == traced.totals
    assert fast.total_cycles == traced.total_cycles
    assert_states_equal(fast.state, traced.state, "(fast vs traced)")


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_livelock_detector_both_backends(backend):
    """DESIGN §4.2: undersized buffers must raise identically whether the
    detector runs host-side (traced) or folded into the device loop."""
    spec = StreamSpec(n_vertices=64, n_edges=400, increments=2, seed=21)
    incs = make_stream(spec)
    cfg = small_cfg(n_vertices=64, edge_cap=2, ghost_slots=48,
                    queue_cap=8, chan_cap=2, futq_cap=2, backend=backend)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    with pytest.raises(RuntimeError, match="livelock"):
        for e in incs:
            eng.run_increment(e, max_cycles=500_000)


def test_fast_path_single_jit_per_pass(monkeypatch):
    """O(1) host<->device syncs: exactly one device-loop invocation per
    spill pass of run_increment (here: one pass -> one call)."""
    import repro.core.engine as engine_mod
    calls = []
    orig = engine_mod._increment_device_loop

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(engine_mod, "_increment_device_loop", counting)
    spec = StreamSpec(n_vertices=128, n_edges=512, increments=2, seed=5)
    incs = make_stream(spec)
    eng, _ = run_bfs(small_cfg(), incs)
    assert len(calls) == len(incs)  # no spill -> one jit call each
