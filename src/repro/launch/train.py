"""End-to-end fault-tolerant training driver.

Runs REAL steps (CPU-sized by default; pass --arch/--preset for bigger).
Integrates every production component: deterministic resumable data
pipeline, AdamW, atomic+async checkpointing, straggler watchdog, optional
int8 gradient compression (inter-pod axis), crash-restart resume.

  PYTHONPATH=src python -m repro.launch.train --steps 100 \
      --ckpt-dir /tmp/ckpt --preset lm100m
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import LMBatchSpec, lm_batch
from repro.dist.pipeline import pipelined_apply, split_stages
from repro.models.common import rms_norm
from repro.models.transformer import (LMConfig, _layer, init_lm_params,
                                      lm_loss, wcast)
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
from repro.optim.compression import (compress_with_feedback, decompress,
                                     init_residuals)
from repro.train.checkpoint import Checkpointer
from repro.train.elastic import StepWatchdog

PRESETS = {
    # ~100M-param model (deliverable b) — run on real accelerators;
    # CPU CI uses lm_tiny.
    "lm100m": LMConfig(name="lm100m", n_layers=12, d_model=768, n_heads=12,
                       n_kv_heads=4, d_ff=2048, vocab=32768, remat=False),
    "lm_tiny": LMConfig(name="lm_tiny", n_layers=2, d_model=128, n_heads=4,
                        n_kv_heads=2, d_ff=256, vocab=512, remat=False,
                        attn_chunk=64),
    # pipeline-parallel preset: 4 layers split into --pipeline-stages
    # contiguous stages (GPipe microbatch schedule, repro.dist.pipeline)
    "lm_pipe": LMConfig(name="lm_pipe", n_layers=4, d_model=128, n_heads=4,
                        n_kv_heads=2, d_ff=256, vocab=512, remat=False,
                        attn_chunk=64),
}


def make_pipeline_loss(cfg: LMConfig, n_stages: int, mesh=None,
                       n_micro: int | None = None):
    """LM loss with the layer stack run through ``pipelined_apply``.

    The transformer's parameters are already layer-stacked (the forward
    is a ``lax.scan`` over them), so ``split_stages`` carves them into S
    contiguous stages directly and each stage scans its own [L/S, ...]
    slice.  The batch axis supplies the microbatches.  With ``mesh``
    None (or no 'pipe' axis) ``pipelined_apply`` runs its sequential
    fallback, so the same loss traces on one host.
    """
    if cfg.n_experts:
        raise ValueError("pipeline loss supports dense FFN presets only "
                         "(MoE aux loss is not threaded through stages)")
    M = n_micro or n_stages

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        B, T = tokens.shape
        if B % M:
            raise ValueError(f"batch {B} not divisible into {M} microbatches")
        mb = B // M
        positions = jnp.broadcast_to(jnp.arange(T)[None, :], (mb, T))
        x = wcast(params["embed"], cfg, "model", None)[tokens]
        xs = x.reshape(M, mb, T, x.shape[-1])
        stages = split_stages(params["layers"], n_stages)

        def stage_fn(sp, h):
            def body(h, lp):
                h2, _, _ = _layer(cfg, lp, h, positions)
                return h2, None
            h, _ = jax.lax.scan(body, h, sp)
            return h

        x = pipelined_apply(stage_fn, stages, xs, mesh)
        x = rms_norm(x.reshape(B, T, x.shape[-1]), params["final_norm"])
        logits = x @ wcast(params["unembed"], cfg, "dp", None)
        tgt = jnp.take_along_axis(logits, batch["targets"][..., None],
                                  -1)[..., 0].astype(jnp.float32)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        return (lse - tgt).mean()

    return loss_fn


def make_train_step(cfg: LMConfig, opt_cfg: AdamWConfig, compress: bool,
                    pipeline_stages: int = 0, mesh=None,
                    n_micro: int | None = None):
    loss_fn = (make_pipeline_loss(cfg, pipeline_stages, mesh, n_micro)
               if pipeline_stages > 1
               else functools.partial(lm_loss, cfg))

    @jax.jit
    def step(params, opt, residuals, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch))(params)
        if compress:
            # inter-pod gradient path: int8 + error feedback
            comp, residuals = compress_with_feedback(grads, residuals)
            grads = decompress(comp, grads)
        params, opt, gnorm = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, residuals, loss, gnorm
    return step


def train(cfg: LMConfig, steps: int, batch: int, seq: int,
          ckpt_dir: str | None, ckpt_every: int = 50, compress: bool = False,
          watchdog_s: float = 0.0, log_every: int = 10, seed: int = 0,
          pipeline_stages: int = 0, n_micro: int | None = None):
    opt_cfg = AdamWConfig(total_steps=steps)
    mesh = None
    if pipeline_stages > 1 and len(jax.devices()) >= pipeline_stages:
        # enough devices: real GPipe schedule over a 'pipe' mesh axis;
        # otherwise make_pipeline_loss runs the sequential fallback
        mesh = jax.make_mesh((pipeline_stages,), ("pipe",))
        print(f"[train] pipeline: {pipeline_stages} stages over "
              f"{len(mesh.devices.flat)} devices")
    elif pipeline_stages > 1:
        print(f"[train] pipeline: {pipeline_stages} stages, sequential "
              f"fallback ({len(jax.devices())} device(s))")
    params = init_lm_params(cfg, jax.random.PRNGKey(seed))
    opt = init_adamw(params)
    residuals = init_residuals(params) if compress else \
        jax.tree.map(lambda x: jnp.zeros((), jnp.float32), params)
    bspec = LMBatchSpec(batch=batch, seq_len=seq, vocab=cfg.vocab, seed=seed)
    start = 0
    ck = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ck and ck.latest_step() is not None:
        (params, opt, residuals), extra, start = ck.restore(
            (params, opt, residuals))
        print(f"[train] resumed from step {start} ({extra})")
    step_fn = make_train_step(cfg, opt_cfg, compress,
                              pipeline_stages, mesh, n_micro)
    wd = StepWatchdog(watchdog_s) if watchdog_s > 0 else None
    losses = []
    t0 = time.time()
    for s in range(start, steps):
        if wd:
            wd.arm(s)
        b = {k: jnp.asarray(v) for k, v in lm_batch(bspec, s).items()}
        params, opt, residuals, loss, gnorm = step_fn(
            params, opt, residuals, b)
        if wd:
            wd.disarm()
        losses.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            dt = time.time() - t0
            print(f"[train] step {s} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} ({dt:.1f}s)")
        if ck and (s + 1) % ckpt_every == 0:
            ck.save_async(s + 1, (params, opt, residuals),
                          extra=dict(loss=float(loss)))
    if ck:
        ck.wait()
        ck.save(steps, (params, opt, residuals),
                extra=dict(loss=losses[-1]))
    return params, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="lm_tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--watchdog-s", type=float, default=0.0)
    ap.add_argument("--pipeline-stages", type=int, default=0,
                    help="split the layer stack into N pipeline stages "
                         "(GPipe microbatch schedule; 0/1 = off)")
    ap.add_argument("--micro", type=int, default=None,
                    help="microbatch count for the pipeline schedule "
                         "(default: one per stage)")
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    _, losses = train(cfg, args.steps, args.batch, args.seq,
                      args.ckpt_dir, args.ckpt_every, args.compress,
                      args.watchdog_s,
                      pipeline_stages=args.pipeline_stages,
                      n_micro=args.micro)
    print(f"[train] done. first loss {losses[0]:.4f} -> "
          f"last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
