"""Jitted wrappers: pick Pallas on TPU, interpret-mode on CPU tests."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                    interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_fwd(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=interpret)


attention_reference = jax.jit(attention_ref, static_argnames=("causal",))
