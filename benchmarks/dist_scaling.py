"""bench_dist: chunk throughput of the GSPMD-sharded CCA engine vs device
count.  The XLA fake-device count is locked at first jax init, so each
device count runs in its own subprocess (worker mode below); the driver
collects ``results/bench_dist.json`` so the perf trajectory captures
scaling (ISSUE 2 satellite).

  PYTHONPATH=src python -m benchmarks.run --scale ci --only dist
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

# scale -> (height, width, n_vertices, stream_edges, chunk, timed_chunks)
SCALES = {
    "ci": (8, 8, 64, 160, 32, 6),
    "mid": (16, 16, 1024, 4096, 64, 8),
    "paper": (32, 32, 50_000, 102_000, 128, 8),
}


def worker(scale: str, devices: int) -> dict:
    """Runs inside a subprocess whose XLA_FLAGS pin the device count."""
    import jax
    import numpy as np
    from repro.core.apps import BFS
    from repro.core.config import EngineConfig
    from repro.core.engine import StreamingEngine, run_chunk_body
    from repro.core.ingest import load_stream
    from repro.dist.compat import AxisType, make_mesh
    from repro.dist.sharding import cca_state_shardings

    H, W, V, E, chunk, timed = SCALES[scale]
    cfg = EngineConfig(height=H, width=W, n_vertices=V,
                       ghost_slots=max(16, 4 * V // (H * W)),
                       io_stream_cap=max(256, 2 * E // W), chunk=chunk)
    rng = np.random.default_rng(0)
    one = np.float32(1.0).view(np.int32)
    edges = np.stack([rng.integers(0, V, E), rng.integers(0, V, E),
                      np.full(E, one)], 1).astype(np.int32)
    eng = StreamingEngine(cfg, "bfs")
    eng.seed(0, 0.0)
    cfg = eng.cfg
    st, _ = load_stream(cfg, eng.state, edges)

    mesh = make_mesh((devices, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    shards = cca_state_shardings(mesh, jax.eval_shape(lambda: st))
    st = jax.device_put(st, shards)
    step = jax.jit(lambda s: run_chunk_body(cfg, BFS, s),
                   in_shardings=(shards,), out_shardings=shards)
    t0 = time.time()
    st = jax.block_until_ready(step(st))          # compile + warm
    compile_s = time.time() - t0
    t0 = time.time()
    for _ in range(timed):
        st = step(st)
    jax.block_until_ready(st)
    wall = time.time() - t0
    cycles = timed * cfg.chunk
    return dict(devices=devices, grid=f"{H}x{W}", chunk=cfg.chunk,
                timed_chunks=timed, compile_s=round(compile_s, 2),
                wall_s=round(wall, 4),
                cell_cycles_per_s=round(H * W * cycles / wall, 1))


def run_scaling(scale: str, device_counts=(1, 2, 4, 8),
                out_path: str = "results/bench_dist.json") -> list[dict]:
    rows = []
    for d in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        env.setdefault("PYTHONPATH", "src")
        try:
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.dist_scaling",
                 "--worker", "--scale", scale, "--devices", str(d)],
                env=env, capture_output=True, text=True, timeout=1800)
        except subprocess.TimeoutExpired:
            rows.append(dict(devices=d, error="worker timeout (1800s)"))
            continue
        if r.returncode != 0:
            rows.append(dict(devices=d, error=r.stderr[-500:]))
            continue
        rows.append(json.loads(r.stdout.splitlines()[-1]))
    p = pathlib.Path(out_path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(dict(scale=scale, rows=rows), indent=1))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="ci", choices=sorted(SCALES))
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--worker", action="store_true")
    args = ap.parse_args()
    if args.worker:
        print(json.dumps(worker(args.scale, args.devices)), flush=True)
    else:
        for row in run_scaling(args.scale):
            print(row)


if __name__ == "__main__":
    main()
